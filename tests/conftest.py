# NB: no XLA_FLAGS here on purpose — smoke tests and benches must see
# the real single CPU device; only launch/dryrun.py forces 512
# placeholder devices (and only in its own process).
import os
import warnings

import pytest

warnings.filterwarnings(
    "ignore", message=".*default axis_types will change.*")

# Opt-in runtime lock-discipline checking (CI runs the suite once with
# this on): every Lock/RLock/Condition created by repro code becomes an
# instrumented wrapper that records acquisition order and raises on an
# observed inversion or an over-long hold. Installed at conftest import
# time, before any repro module constructs a lock.
_LOCK_CHECK = os.environ.get("REPRO_LOCK_CHECK") == "1"
if _LOCK_CHECK:
    from repro.analysis import instrumented

    instrumented.install()


@pytest.fixture(autouse=True, scope="session")
def _lock_discipline():
    """Fail the run if any instrumented lock recorded a violation —
    including ones raised on daemon threads, where the raise alone
    would vanish into a thread's stderr instead of failing a test."""
    yield
    if not _LOCK_CHECK:
        return
    from repro.analysis import instrumented

    violations = instrumented.violations()
    assert not violations, (
        "lock-discipline violations observed during the test run:\n"
        + "\n".join(f"  - {v}" for v in violations))
