"""Pallas kernel validation: shape/dtype sweeps vs. pure-jnp oracles,
executed in interpret mode on CPU (TPU is the lowering target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # optional dep

from repro.kernels.ops import flash_attention_op, flash_decode_op
from repro.kernels.ref import ref_flash_attention, ref_flash_decode

RNG = np.random.default_rng(0)


def mk(shape, dt):
    return jnp.asarray(RNG.standard_normal(shape), dt)


PREFILL_CASES = [
    # b, hq, hk, sq, sk, d, causal, window, dtype
    (2, 8, 2, 256, 256, 64, True, None, jnp.float32),    # GQA
    (1, 4, 4, 128, 128, 128, True, None, jnp.float32),   # MHA
    (1, 4, 4, 128, 128, 128, True, None, jnp.bfloat16),  # bf16
    (2, 8, 8, 256, 256, 120, True, 64, jnp.float32),     # SWA + d=120
    (1, 4, 2, 128, 128, 80, False, None, jnp.float32),   # encoder, d=80
    (1, 16, 4, 384, 384, 96, True, 128, jnp.bfloat16),   # odd sizes
    (1, 8, 1, 256, 256, 64, True, None, jnp.float32),    # MQA
]


@pytest.mark.parametrize("case", PREFILL_CASES,
                         ids=[f"h{c[1]}/{c[2]}_s{c[3]}_d{c[5]}"
                              f"_c{int(c[6])}_w{c[7]}_{c[8].__name__}"
                              for c in PREFILL_CASES])
def test_flash_attention_matches_oracle(case):
    b, hq, hk, sq, sk, d, causal, window, dt = case
    q, k, v = (mk((b, sq, hq, d), dt), mk((b, sk, hk, d), dt),
               mk((b, sk, hk, d), dt))
    out = flash_attention_op(q, k, v, causal=causal, window=window,
                             block_q=128, block_k=128, interpret=True)
    ref = jnp.swapaxes(
        ref_flash_attention(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                            jnp.swapaxes(v, 1, 2), causal=causal,
                            window=window), 1, 2)
    tol = 2.5e-2 if dt == jnp.bfloat16 else 3e-5
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err < tol, err


DECODE_CASES = [
    (4, 8, 2, 512, 64, jnp.float32),
    (2, 8, 8, 256, 128, jnp.bfloat16),
    (3, 16, 4, 384, 120, jnp.float32),
    (1, 8, 1, 128, 128, jnp.float32),
    (2, 32, 8, 256, 80, jnp.bfloat16),
]


@pytest.mark.parametrize("case", DECODE_CASES,
                         ids=[f"h{c[1]}/{c[2]}_s{c[3]}_d{c[4]}"
                              f"_{c[5].__name__}" for c in DECODE_CASES])
def test_flash_decode_matches_oracle(case):
    b, hq, hk, s, d, dt = case
    q = mk((b, 1, hq, d), dt)
    kc, vc = mk((b, s, hk, d), dt), mk((b, s, hk, d), dt)
    lengths = jnp.asarray(RNG.integers(1, s + 1, (b,)), jnp.int32)
    out = flash_decode_op(q, kc, vc, lengths, block_k=128, interpret=True)
    ref = ref_flash_decode(q[:, 0], jnp.swapaxes(kc, 1, 2),
                           jnp.swapaxes(vc, 1, 2), lengths)
    tol = 3e-2 if dt == jnp.bfloat16 else 3e-5
    err = float(jnp.max(jnp.abs(out[:, 0].astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err < tol, err


@given(st.integers(1, 3), st.sampled_from([1, 2, 4]),
       st.sampled_from([64, 128]), st.sampled_from([128, 256]),
       st.booleans())
@settings(max_examples=12, deadline=None)
def test_flash_attention_property(b, g, d, s, causal):
    """Property sweep: random GQA group sizes / dims / causality."""
    hk = 2
    hq = hk * g
    q, k, v = (mk((b, s, hq, d), jnp.float32),
               mk((b, s, hk, d), jnp.float32),
               mk((b, s, hk, d), jnp.float32))
    out = flash_attention_op(q, k, v, causal=causal, block_q=128,
                             block_k=128, interpret=True)
    ref = jnp.swapaxes(
        ref_flash_attention(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                            jnp.swapaxes(v, 1, 2), causal=causal), 1, 2)
    assert float(jnp.max(jnp.abs(out - ref))) < 3e-5


def test_decode_length_one_vs_full():
    """lengths=1 attends only to slot 0; lengths=S uses everything."""
    b, hq, hk, s, d = 2, 4, 2, 128, 64
    q = mk((b, 1, hq, d), jnp.float32)
    kc, vc = mk((b, s, hk, d), jnp.float32), mk((b, s, hk, d), jnp.float32)
    out1 = flash_decode_op(q, kc, vc, jnp.ones((b,), jnp.int32),
                           block_k=128, interpret=True)
    # with length 1, output = v[0] per kv head group exactly (softmax of 1)
    expect = jnp.repeat(vc[:, 0][:, None], hq // hk, axis=2
                        ).reshape(b, 1, hq, d)
    assert float(jnp.max(jnp.abs(out1 - expect))) < 1e-5


def test_kernel_agrees_with_model_attention():
    """The kernels and the model's XLA chunked attention implement the
    same math (three-way agreement)."""
    from repro.models.layers import attention_chunked
    b, hq, hk, s, d = 1, 8, 2, 256, 64
    q, k, v = (mk((b, s, hq, d), jnp.float32),
               mk((b, s, hk, d), jnp.float32),
               mk((b, s, hk, d), jnp.float32))
    xla = attention_chunked(q, k, v, causal=True, chunk=64)
    pallas = flash_attention_op(q, k, v, causal=True, block_q=128,
                                block_k=128, interpret=True)
    assert float(jnp.max(jnp.abs(xla - pallas))) < 3e-5


def test_model_pallas_impl_matches_xla():
    """cfg.attention_impl='pallas_interpret' must reproduce the XLA path
    through the full model (train fwd + prefill + decode)."""
    import jax
    from repro.configs import SMOKE_ARCHS
    from repro.models import model as MD

    cfg_x = SMOKE_ARCHS["granite-8b"].with_overrides(dtype="float32",
                                                     attn_chunk=16)
    cfg_p = cfg_x.with_overrides(attention_impl="pallas_interpret")
    rng = jax.random.PRNGKey(0)
    params = MD.init_params(rng, cfg_x)
    toks = jax.random.randint(rng, (2, 18), 0, cfg_x.vocab_size)

    hx, _, _ = MD.forward_hidden(params, cfg_x, {"tokens": toks}, "train")
    hp, _, _ = MD.forward_hidden(params, cfg_p, {"tokens": toks}, "train")
    assert float(jnp.max(jnp.abs(hx - hp))) < 2e-4

    cache_x = MD.init_cache(cfg_x, 2, 18)
    cache_p = MD.init_cache(cfg_p, 2, 18)
    lx, cache_x = MD.prefill(params, cfg_x, {"tokens": toks[:, :16]},
                             cache_x)
    lp, cache_p = MD.prefill(params, cfg_p, {"tokens": toks[:, :16]},
                             cache_p)
    assert float(jnp.max(jnp.abs(lx - lp))) < 2e-3
    for t in range(2):
        nb = {"tokens": toks[:, 16 + t:17 + t]}
        lx, cache_x = MD.decode_step(params, cfg_x, nb, cache_x)
        lp, cache_p = MD.decode_step(params, cfg_p, nb, cache_p)
        assert float(jnp.max(jnp.abs(lx - lp))) < 2e-3


# ---------------------------------------------------------------------------
# Paged decode kernel (block tables walked in place)
# ---------------------------------------------------------------------------


def _paged_case(rng, b, hk, g, d, bs, bps, num_blocks, dt):
    """Random paged scenario: pages with a NaN-poisoned trash block,
    per-row tables of distinct physical ids, ragged lengths."""
    hq = hk * g
    k_pages = rng.standard_normal((num_blocks, hk, bs, d)).astype(dt)
    v_pages = rng.standard_normal((num_blocks, hk, bs, d)).astype(dt)
    # Block 0 is the trash block: decode writes of free rows land there,
    # so it is realistically full of NaN. The kernel must never let it
    # poison a live row.
    k_pages[0] = np.nan
    v_pages[0] = np.nan
    q = rng.standard_normal((b, 1, hq, d)).astype(dt)
    lengths = rng.integers(1, bps * bs + 1, b).astype(np.int32)
    tables = np.full((b, bps), -1, np.int32)
    free = list(rng.permutation(np.arange(1, num_blocks)))
    for row in range(b):
        for j in range(-(-int(lengths[row]) // bs)):
            tables[row, j] = free.pop()
    return q, k_pages, v_pages, tables, lengths


PAGED_CASES = [
    # b, hk, g, d, bs, bps, num_blocks, dtype
    (4, 2, 4, 64, 16, 4, 40, np.float32),
    (2, 4, 1, 128, 8, 8, 80, np.float32),
    (3, 1, 8, 80, 16, 3, 16, np.float32),
    (2, 2, 2, 64, 16, 4, 24, np.float32),
]


@pytest.mark.parametrize(
    "case", PAGED_CASES,
    ids=[f"b{c[0]}_h{c[1] * c[2]}/{c[1]}_d{c[3]}_bs{c[4]}x{c[5]}"
         for c in PAGED_CASES])
def test_paged_decode_matches_gathered_reference(case):
    """The kernel must agree with the gathered-view oracle — and agree
    EXACTLY (==, the bit-exactness gate) with the gathered view run
    through flash_decode at block_k=block_size, whose accumulation
    order it reproduces block for block."""
    from repro.kernels.decode_attention import flash_decode
    from repro.kernels.ops import paged_flash_decode_op
    from repro.kernels.ref import ref_paged_decode
    b, hk, g, d, bs, bps, num_blocks, dt = case
    rng = np.random.default_rng(b * 1000 + d)
    q, kp, vp, tables, lengths = _paged_case(rng, b, hk, g, d, bs, bps,
                                             num_blocks, dt)
    out = paged_flash_decode_op(q, kp, vp, tables, lengths,
                                interpret=True)
    ref = ref_paged_decode(jnp.asarray(q[:, 0]), jnp.asarray(kp),
                           jnp.asarray(vp), jnp.asarray(tables),
                           jnp.asarray(lengths))
    err = float(jnp.max(jnp.abs(out[:, 0] - ref)))
    assert err < 3e-5, err

    # Bit-exactness gate vs the gathered-view fallback path.
    tab = np.where(tables < 0, 0, tables)
    kg = np.moveaxis(kp[tab], 2, 1).reshape(b, hk, bps * bs, d)
    vg = np.moveaxis(vp[tab], 2, 1).reshape(b, hk, bps * bs, d)
    live = np.arange(bps * bs)[None] < lengths[:, None]
    kg = np.where(live[:, None, :, None], kg, 0)
    vg = np.where(live[:, None, :, None], vg, 0)
    gathered = flash_decode(jnp.asarray(q[:, 0]), jnp.asarray(kg),
                            jnp.asarray(vg), jnp.asarray(lengths),
                            block_k=bs, interpret=True)
    np.testing.assert_array_equal(np.asarray(out[:, 0]),
                                  np.asarray(gathered))


def test_paged_decode_num_blocks_beyond_gatherable_capacity():
    """The pool may hold far more physical blocks than every slot
    combined could ever gather (num_blocks >> num_slots * bps + 1): the
    kernel only chases table entries, so high physical ids just work."""
    from repro.kernels.ops import paged_flash_decode_op
    from repro.kernels.ref import ref_paged_decode
    b, hk, g, d, bs, bps = 2, 2, 2, 64, 16, 2
    num_blocks = 512                     # gatherable would be b*bps+1 = 5
    rng = np.random.default_rng(3)
    q, kp, vp, tables, lengths = _paged_case(rng, b, hk, g, d, bs, bps,
                                             num_blocks, np.float32)
    # pin the tables to the TOP of the pool — ids a gathered view of a
    # right-sized pool could never express
    for row in range(b):
        for j in range(bps):
            if tables[row, j] >= 0:
                tables[row, j] = num_blocks - 1 - (row * bps + j)
    out = paged_flash_decode_op(q, kp, vp, tables, lengths,
                                interpret=True)
    ref = ref_paged_decode(jnp.asarray(q[:, 0]), jnp.asarray(kp),
                           jnp.asarray(vp), jnp.asarray(tables),
                           jnp.asarray(lengths))
    assert float(jnp.max(jnp.abs(out[:, 0] - ref))) < 3e-5


@given(st.integers(1, 4), st.sampled_from([1, 2, 4]),
       st.sampled_from([8, 16]), st.integers(2, 5),
       st.integers(0, 60), st.integers(0, 2 ** 32 - 1))
@settings(max_examples=10, deadline=None)
def test_paged_decode_property(b, g, bs, bps, extra_blocks, seed):
    """Property sweep: random block sizes / table shapes / ragged
    lengths / pool sizes (including beyond gatherable capacity)."""
    from repro.kernels.ops import paged_flash_decode_op
    from repro.kernels.ref import ref_paged_decode
    hk, d = 2, 64
    num_blocks = 1 + b * bps + extra_blocks
    rng = np.random.default_rng(seed)
    q, kp, vp, tables, lengths = _paged_case(rng, b, hk, g, d, bs, bps,
                                             num_blocks, np.float32)
    out = paged_flash_decode_op(q, kp, vp, tables, lengths,
                                interpret=True)
    ref = ref_paged_decode(jnp.asarray(q[:, 0]), jnp.asarray(kp),
                           jnp.asarray(vp), jnp.asarray(tables),
                           jnp.asarray(lengths))
    assert float(jnp.max(jnp.abs(out[:, 0] - ref))) < 3e-5
