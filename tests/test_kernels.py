"""Pallas kernel validation: shape/dtype sweeps vs. pure-jnp oracles,
executed in interpret mode on CPU (TPU is the lowering target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # optional dep

from repro.kernels.ops import flash_attention_op, flash_decode_op
from repro.kernels.ref import ref_flash_attention, ref_flash_decode

RNG = np.random.default_rng(0)


def mk(shape, dt):
    return jnp.asarray(RNG.standard_normal(shape), dt)


PREFILL_CASES = [
    # b, hq, hk, sq, sk, d, causal, window, dtype
    (2, 8, 2, 256, 256, 64, True, None, jnp.float32),    # GQA
    (1, 4, 4, 128, 128, 128, True, None, jnp.float32),   # MHA
    (1, 4, 4, 128, 128, 128, True, None, jnp.bfloat16),  # bf16
    (2, 8, 8, 256, 256, 120, True, 64, jnp.float32),     # SWA + d=120
    (1, 4, 2, 128, 128, 80, False, None, jnp.float32),   # encoder, d=80
    (1, 16, 4, 384, 384, 96, True, 128, jnp.bfloat16),   # odd sizes
    (1, 8, 1, 256, 256, 64, True, None, jnp.float32),    # MQA
]


@pytest.mark.parametrize("case", PREFILL_CASES,
                         ids=[f"h{c[1]}/{c[2]}_s{c[3]}_d{c[5]}"
                              f"_c{int(c[6])}_w{c[7]}_{c[8].__name__}"
                              for c in PREFILL_CASES])
def test_flash_attention_matches_oracle(case):
    b, hq, hk, sq, sk, d, causal, window, dt = case
    q, k, v = (mk((b, sq, hq, d), dt), mk((b, sk, hk, d), dt),
               mk((b, sk, hk, d), dt))
    out = flash_attention_op(q, k, v, causal=causal, window=window,
                             block_q=128, block_k=128, interpret=True)
    ref = jnp.swapaxes(
        ref_flash_attention(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                            jnp.swapaxes(v, 1, 2), causal=causal,
                            window=window), 1, 2)
    tol = 2.5e-2 if dt == jnp.bfloat16 else 3e-5
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err < tol, err


DECODE_CASES = [
    (4, 8, 2, 512, 64, jnp.float32),
    (2, 8, 8, 256, 128, jnp.bfloat16),
    (3, 16, 4, 384, 120, jnp.float32),
    (1, 8, 1, 128, 128, jnp.float32),
    (2, 32, 8, 256, 80, jnp.bfloat16),
]


@pytest.mark.parametrize("case", DECODE_CASES,
                         ids=[f"h{c[1]}/{c[2]}_s{c[3]}_d{c[4]}"
                              f"_{c[5].__name__}" for c in DECODE_CASES])
def test_flash_decode_matches_oracle(case):
    b, hq, hk, s, d, dt = case
    q = mk((b, 1, hq, d), dt)
    kc, vc = mk((b, s, hk, d), dt), mk((b, s, hk, d), dt)
    lengths = jnp.asarray(RNG.integers(1, s + 1, (b,)), jnp.int32)
    out = flash_decode_op(q, kc, vc, lengths, block_k=128, interpret=True)
    ref = ref_flash_decode(q[:, 0], jnp.swapaxes(kc, 1, 2),
                           jnp.swapaxes(vc, 1, 2), lengths)
    tol = 3e-2 if dt == jnp.bfloat16 else 3e-5
    err = float(jnp.max(jnp.abs(out[:, 0].astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err < tol, err


@given(st.integers(1, 3), st.sampled_from([1, 2, 4]),
       st.sampled_from([64, 128]), st.sampled_from([128, 256]),
       st.booleans())
@settings(max_examples=12, deadline=None)
def test_flash_attention_property(b, g, d, s, causal):
    """Property sweep: random GQA group sizes / dims / causality."""
    hk = 2
    hq = hk * g
    q, k, v = (mk((b, s, hq, d), jnp.float32),
               mk((b, s, hk, d), jnp.float32),
               mk((b, s, hk, d), jnp.float32))
    out = flash_attention_op(q, k, v, causal=causal, block_q=128,
                             block_k=128, interpret=True)
    ref = jnp.swapaxes(
        ref_flash_attention(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                            jnp.swapaxes(v, 1, 2), causal=causal), 1, 2)
    assert float(jnp.max(jnp.abs(out - ref))) < 3e-5


def test_decode_length_one_vs_full():
    """lengths=1 attends only to slot 0; lengths=S uses everything."""
    b, hq, hk, s, d = 2, 4, 2, 128, 64
    q = mk((b, 1, hq, d), jnp.float32)
    kc, vc = mk((b, s, hk, d), jnp.float32), mk((b, s, hk, d), jnp.float32)
    out1 = flash_decode_op(q, kc, vc, jnp.ones((b,), jnp.int32),
                           block_k=128, interpret=True)
    # with length 1, output = v[0] per kv head group exactly (softmax of 1)
    expect = jnp.repeat(vc[:, 0][:, None], hq // hk, axis=2
                        ).reshape(b, 1, hq, d)
    assert float(jnp.max(jnp.abs(out1 - expect))) < 1e-5


def test_kernel_agrees_with_model_attention():
    """The kernels and the model's XLA chunked attention implement the
    same math (three-way agreement)."""
    from repro.models.layers import attention_chunked
    b, hq, hk, s, d = 1, 8, 2, 256, 64
    q, k, v = (mk((b, s, hq, d), jnp.float32),
               mk((b, s, hk, d), jnp.float32),
               mk((b, s, hk, d), jnp.float32))
    xla = attention_chunked(q, k, v, causal=True, chunk=64)
    pallas = flash_attention_op(q, k, v, causal=True, block_q=128,
                                block_k=128, interpret=True)
    assert float(jnp.max(jnp.abs(xla - pallas))) < 3e-5


def test_model_pallas_impl_matches_xla():
    """cfg.attention_impl='pallas_interpret' must reproduce the XLA path
    through the full model (train fwd + prefill + decode)."""
    import jax
    from repro.configs import SMOKE_ARCHS
    from repro.models import model as MD

    cfg_x = SMOKE_ARCHS["granite-8b"].with_overrides(dtype="float32",
                                                     attn_chunk=16)
    cfg_p = cfg_x.with_overrides(attention_impl="pallas_interpret")
    rng = jax.random.PRNGKey(0)
    params = MD.init_params(rng, cfg_x)
    toks = jax.random.randint(rng, (2, 18), 0, cfg_x.vocab_size)

    hx, _, _ = MD.forward_hidden(params, cfg_x, {"tokens": toks}, "train")
    hp, _, _ = MD.forward_hidden(params, cfg_p, {"tokens": toks}, "train")
    assert float(jnp.max(jnp.abs(hx - hp))) < 2e-4

    cache_x = MD.init_cache(cfg_x, 2, 18)
    cache_p = MD.init_cache(cfg_p, 2, 18)
    lx, cache_x = MD.prefill(params, cfg_x, {"tokens": toks[:, :16]},
                             cache_x)
    lp, cache_p = MD.prefill(params, cfg_p, {"tokens": toks[:, :16]},
                             cache_p)
    assert float(jnp.max(jnp.abs(lx - lp))) < 2e-3
    for t in range(2):
        nb = {"tokens": toks[:, 16 + t:17 + t]}
        lx, cache_x = MD.decode_step(params, cfg_x, nb, cache_x)
        lp, cache_p = MD.decode_step(params, cfg_p, nb, cache_p)
        assert float(jnp.max(jnp.abs(lx - lp))) < 2e-3
