"""All-to-all MoE (beyond-paper §Perf H-A): fallback semantics in-process
+ numeric equivalence with the grouped path on an 8-device mesh
(subprocess — device count is locked at jax init)."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np

from repro.models.moe import init_moe, moe_apply
from repro.models.moe_a2a import current_mesh, moe_apply_a2a


def test_fallback_without_mesh_matches_grouped():
    p = init_moe(jax.random.PRNGKey(0), 32, 64, 4)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    assert current_mesh() is None
    y1, _ = moe_apply_a2a(p, x, top_k=2, capacity_factor=4.0)
    y2, _ = moe_apply(p, x, top_k=2, capacity_factor=4.0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)


SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.moe import init_moe, moe_apply
    from repro.models.moe_a2a import mesh_context, moe_apply_a2a
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    e, d, f, k = 8, 64, 128, 2
    p = init_moe(jax.random.PRNGKey(0), d, f, e)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, d), jnp.float32)
    ref, _ = moe_apply(p, x, top_k=k, capacity_factor=float(e))
    with mesh_context(mesh):
        y, _ = jax.jit(lambda p, x: moe_apply_a2a(
            p, x, top_k=k, capacity_factor=float(e)))(p, x)
        txt = jax.jit(lambda p, x: moe_apply_a2a(
            p, x, top_k=k, capacity_factor=float(e))[0]).lower(
            p, x).compile().as_text()
    err = float(jnp.max(jnp.abs(y - ref)))
    assert err < 1e-4, err
    assert "all-to-all" in txt, "a2a collective missing from HLO"
    print("A2A_OK", err)
""")


def test_a2a_matches_grouped_on_8_device_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300,
                         cwd=os.path.join(os.path.dirname(__file__),
                                          ".."))
    assert "A2A_OK" in out.stdout, out.stdout + out.stderr
