"""Synthetic pipeline tests: determinism, shape contracts, learnability
structure (the Markov table must make next tokens predictable)."""
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM


def test_deterministic_batches():
    cfg = get_config("tfs-classifier", smoke=True)
    d1 = SyntheticLM(DataConfig(seed=7), cfg.vocab_size)
    d2 = SyntheticLM(DataConfig(seed=7), cfg.vocab_size)
    b1 = next(d1.batches(cfg))
    b2 = next(d2.batches(cfg))
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_labels_are_shifted_tokens():
    cfg = get_config("tfs-classifier", smoke=True)
    data = SyntheticLM(DataConfig(batch_size=2, seq_len=32),
                       cfg.vocab_size)
    b = next(data.batches(cfg))
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_markov_structure_predicts():
    cfg = get_config("tfs-classifier", smoke=True)
    dc = DataConfig(batch_size=4, seq_len=256, determinism=0.95)
    data = SyntheticLM(dc, cfg.vocab_size)
    b = next(data.batches(cfg))
    toks = b["tokens"]
    pred = data.table[toks[:, :-2], toks[:, 1:-1]]
    acc = float(np.mean(pred == toks[:, 2:]))
    assert acc > 0.8  # ~determinism
    assert data.structure_nats() < 0.5 * data.uniform_nats()


def test_embedding_models_get_embeds():
    cfg = get_config("hubert-xlarge", smoke=True)
    data = SyntheticLM(DataConfig(batch_size=2, seq_len=16),
                       cfg.vocab_size)
    b = next(data.batches(cfg))
    assert b["embeds"].shape == (2, 16, cfg.d_model)
    assert b["embeds"].dtype == np.float32
