"""TFS² instances/partitions tests (paper §3.1 Temp/Prod + §3.2 flow)."""
import pytest

from repro.core import (CallableLoader, RawDictServable, ResourceEstimate,
                        ServableId)
from repro.hosted.controller import AdmissionError
from repro.hosted.instances import (Instance, Partition, PartitionSpec,
                                    Tfs2Service)


def loader_factory(name, version, ref, ram):
    sid = ServableId(name, version)
    return CallableLoader(
        sid, lambda: RawDictServable(sid, {"v": version}, ram_bytes=ram),
        ResourceEstimate(ram_bytes=ram))


@pytest.fixture()
def service():
    def part(name, hw, region):
        return Partition(PartitionSpec(
            name, hardware=hw, region=region,
            job_capacities={"j0": 10_000}), loader_factory)
    temp = Instance("temp", [part("t-cpu-us", "cpu", "us")])
    prod = Instance("prod", [part("p-cpu-us", "cpu", "us"),
                             part("p-tpu-us", "tpu", "us"),
                             part("p-cpu-sa", "cpu", "sa")])
    svc = Tfs2Service(temp, prod)
    yield svc
    svc.shutdown()


class TestInstancesPartitions:
    def test_defaults_to_temp(self, service):
        placed = service.add_model("m", 100)
        assert placed.startswith("temp/")
        assert service.serving_instance("m") == "temp"
        assert service.infer("m", "v", method="lookup") == 1

    def test_partition_selection_by_hardware_and_region(self, service):
        p1 = service.add_model("tpu-model", 100, instance="prod",
                               hardware="tpu")
        assert "p-tpu-us" in p1
        p2 = service.add_model("sa-model", 100, instance="prod",
                               region="sa")
        assert "p-cpu-sa" in p2
        with pytest.raises(AdmissionError):
            service.add_model("gpu-model", 100, instance="prod",
                              hardware="gpu")

    def test_temp_to_prod_graduation(self, service):
        service.add_model("m", 100)
        assert service.serving_instance("m") == "temp"
        dest = service.promote_to_prod("m", 100, hardware="cpu",
                                       region="us")
        assert dest.startswith("prod/")
        assert service.infer("m", "v", method="lookup") == 1
        with pytest.raises(KeyError):
            service.promote_to_prod("m", 100)   # already in prod

    def test_binary_canary_gates_prod_rollout(self, service):
        """Paper: canary binary releases in Temp before Prod."""
        temp_part = service.instances["temp"].partitions[0]
        prod_part = service.instances["prod"].partitions[0]
        assert prod_part.binary_version == "v1"
        ok = service.rollout_binary("v2", validate=lambda p: True)
        assert ok and prod_part.binary_version == "v2"
        ok = service.rollout_binary("v3-broken",
                                    validate=lambda p: False)
        assert not ok
        assert temp_part.binary_version == "v3-broken"  # canaried
        assert prod_part.binary_version == "v2"         # protected
