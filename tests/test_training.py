"""Training substrate tests: optimizer math, grad accumulation
equivalence, chunked loss vs direct CE, learning on the synthetic LM."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import model as MD
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.training.train import (chunked_softmax_xent, init_train_state,
    make_train_step)

CFG = get_config("tfs-classifier", smoke=True).with_overrides(
    dtype="float32", num_layers=2, d_model=64, d_ff=128, vocab_size=128,
    num_heads=2, num_kv_heads=2, head_dim=32, loss_chunk=8)


def make_batch(rng, b=4, s=16):
    toks = jax.random.randint(rng, (b, s + 1), 0, CFG.vocab_size)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class TestChunkedLoss:
    def test_matches_direct_ce(self):
        rng = jax.random.PRNGKey(0)
        params = MD.init_params(rng, CFG)
        batch = make_batch(rng)
        hidden, _, _ = MD.forward_hidden(params, CFG, batch, "train")
        loss_c = chunked_softmax_xent(hidden, params["lm_head"],
                                      batch["labels"], chunk=8)
        logits = MD.logits_from_hidden(params, CFG, hidden)
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), -1)
        gold = jnp.take_along_axis(
            logits.astype(jnp.float32),
            batch["labels"][..., None], -1)[..., 0]
        loss_d = jnp.mean(lse - gold)
        assert abs(float(loss_c) - float(loss_d)) < 1e-4

    def test_mask_excludes_tokens(self):
        rng = jax.random.PRNGKey(1)
        params = MD.init_params(rng, CFG)
        batch = make_batch(rng)
        hidden, _, _ = MD.forward_hidden(params, CFG, batch, "train")
        mask = jnp.zeros((4, 16)).at[:, :8].set(1.0)
        full = chunked_softmax_xent(hidden, params["lm_head"],
                                    batch["labels"], 8)
        half = chunked_softmax_xent(hidden, params["lm_head"],
                                    batch["labels"], 8, mask)
        assert abs(float(full) - float(half)) > 1e-6


class TestAdamW:
    def test_moves_toward_minimum(self):
        cfg = AdamWConfig(learning_rate=0.1, warmup_steps=0,
                          weight_decay=0.0, grad_clip_norm=None)
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = adamw_init(cfg, params)
        for _ in range(150):
            grads = {"w": 2 * params["w"]}     # d/dw ||w||^2
            params, state, _ = adamw_update(cfg, grads, state, params)
        assert float(jnp.abs(params["w"]).max()) < 0.5

    def test_grad_clipping(self):
        cfg = AdamWConfig(grad_clip_norm=1.0, warmup_steps=0)
        params = {"w": jnp.ones((4,))}
        state = adamw_init(cfg, params)
        _, _, metrics = adamw_update(cfg, {"w": jnp.full((4,), 100.0)},
                                     state, params)
        assert float(metrics["grad_norm"]) == pytest.approx(200.0)

    def test_bf16_moments_track_f32(self):
        cfg32 = AdamWConfig(warmup_steps=0)
        cfg16 = AdamWConfig(warmup_steps=0, moment_dtype="bfloat16")
        params = {"w": jnp.linspace(-1, 1, 16)}
        s32, s16 = adamw_init(cfg32, params), adamw_init(cfg16, params)
        p32, p16 = params, params
        for i in range(10):
            g = {"w": jnp.sin(jnp.arange(16.0) + i)}
            p32, s32, _ = adamw_update(cfg32, g, s32, p32)
            p16, s16, _ = adamw_update(cfg16, g, s16, p16)
        assert float(jnp.abs(p32["w"] - p16["w"]).max()) < 0.02


class TestGradAccumulation:
    def test_microbatched_step_matches_full(self):
        opt = AdamWConfig(learning_rate=1e-2, warmup_steps=0,
                          grad_clip_norm=None, weight_decay=0.0)
        rng = jax.random.PRNGKey(2)
        batch = make_batch(rng, b=8)
        p0, s0 = init_train_state(rng, CFG, opt)
        step1 = make_train_step(CFG, opt, microbatch=1)
        step4 = make_train_step(CFG, opt, microbatch=4)
        p1, _, m1 = jax.jit(step1)(p0, s0, batch)
        p4, _, m4 = jax.jit(step4)(p0, s0, batch)
        # same data, same update (up to accumulation-order rounding)
        assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
        diff = max(float(jnp.abs(a - b).max())
                   for a, b in zip(jax.tree_util.tree_leaves(p1),
                                   jax.tree_util.tree_leaves(p4)))
        assert diff < 1e-4, diff


class TestLearning:
    def test_loss_drops_on_synthetic_lm(self):
        """Integration: ~50 steps on the order-2 Markov stream must cut
        loss well below uniform."""
        opt = AdamWConfig(learning_rate=5e-3, warmup_steps=5,
                          total_steps=60)
        params, opt_state = init_train_state(jax.random.PRNGKey(0), CFG,
                                             opt)
        step = jax.jit(make_train_step(CFG, opt))
        data = SyntheticLM(DataConfig(batch_size=8, seq_len=64),
                           CFG.vocab_size)
        losses = []
        for i, batch in zip(range(100), data.batches(CFG)):
            params, opt_state, metrics = step(
                params, opt_state,
                {k: jnp.asarray(v) for k, v in batch.items()})
            losses.append(float(metrics["loss"]))
        uniform = data.uniform_nats()
        assert losses[-1] < 0.65 * losses[0], (losses[0], losses[-1])
        assert losses[-1] < 0.75 * uniform
