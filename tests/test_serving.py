"""Serving-layer integration tests: ModelServer end-to-end (load from
disk, predict/classify/regress/generate through batching, canary,
rollback, RAM budget, inference logging, unload frees device memory)."""
import os
import threading

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import NotFoundError, ServableVersionPolicy
from repro.models import model as MD
from repro.serving.engine import JaxModelLoader, JaxModelServable
from repro.serving.server import ModelServer
from repro.core.servable import ServableId
from repro.training.checkpoint import load_checkpoint, save_checkpoint

CFG = get_config("tfs-classifier", smoke=True)


@pytest.fixture()
def model_dir(tmp_path):
    for v in (1, 2):
        params = MD.init_params(jax.random.PRNGKey(v), CFG)
        save_checkpoint(str(tmp_path), "clf", v, params,
                        {"arch": CFG.name})
    return str(tmp_path)


@pytest.fixture()
def server(model_dir):
    srv = ModelServer({"clf": os.path.join(model_dir, "clf")},
                      cfg_for=lambda n: CFG)
    srv.start_sync()
    yield srv
    srv.stop()


def batch(b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    return {"tokens": rng.integers(0, CFG.vocab_size, (b, s))}


class TestModelServer:
    def test_serves_latest_version(self, server):
        assert server.available_models() == {"clf": (2,)}
        out = server.predict("clf", batch())
        assert out.shape == (2, 16, CFG.vocab_size)
        assert not np.any(np.isnan(out))

    def test_batched_equals_unbatched(self, server):
        b = batch()
        out_b = server.predict("clf", b, batched=True)
        out_u = server.predict("clf", b, batched=False)
        np.testing.assert_allclose(out_b, out_u, atol=2e-5)

    def test_concurrent_clients_merge(self, server):
        outs = [None] * 8

        def client(i):
            outs[i] = server.predict("clf", batch(b=1, seed=i))
        ts = [threading.Thread(target=client, args=(i,))
              for i in range(8)]
        [t.start() for t in ts]
        [t.join() for t in ts]
        for i in range(8):
            ref = server.predict("clf", batch(b=1, seed=i),
                                 batched=False)
            # merged batches change matmul batching => bf16 rounding
            np.testing.assert_allclose(outs[i], ref, atol=6e-2)
        stats = server.scheduler.stats()
        merged = sum(q["enqueued"] - q["batches"]
                     for q in stats.values())
        assert merged >= 0  # merging opportunistic on 1-core CI

    def test_classify_regress_generate(self, server):
        res = server.classify("clf", batch(), k=3)
        assert res["classes"].shape == (2, 3)
        assert np.all(np.diff(res["scores"], axis=1) <= 1e-6)
        reg = server.regress("clf", batch())
        assert reg["value"].shape == (2,)
        gen = server.generate("clf", tokens=batch()["tokens"], max_new=4)
        assert gen.shape == (2, 4)
        assert gen.max() < CFG.vocab_size

    def test_canary_and_rollback(self, server):
        server.source.set_policy("clf",
                                 ServableVersionPolicy(mode="canary"))
        server.refresh()
        assert server.available_models() == {"clf": (1, 2)}
        o1 = server.predict("clf", batch(), version=1)
        o2 = server.predict("clf", batch(), version=2)
        assert np.abs(o1 - o2).max() > 1e-4   # different weights
        server.source.set_policy("clf", ServableVersionPolicy(
            mode="specific", specific_version=1))
        server.refresh()
        assert server.available_models() == {"clf": (1,)}
        with pytest.raises(NotFoundError):
            server.predict("clf", batch(), version=2, batched=False)

    def test_concurrent_generate_across_version_transition(self, server):
        """N threads generate on one servable while versions transition:
        every call must complete with correct shapes (continuous-batching
        decode engine + RCU handle path together). The manager drains
        handles before unload, so in-flight slot requests keep live
        params even as their version is being retired."""
        stop = threading.Event()
        lock = threading.Lock()
        errors, outs = [], []

        def client(i):
            rng = np.random.default_rng(i)
            while not stop.is_set():
                toks = rng.integers(0, CFG.vocab_size, (1, 12))
                try:
                    out = server.generate("clf", tokens=toks, max_new=4)
                    with lock:
                        outs.append(out)
                except Exception as exc:        # any failure is a bug
                    with lock:
                        errors.append(exc)
                    return
        ts = [threading.Thread(target=client, args=(i,))
              for i in range(6)]
        [t.start() for t in ts]
        try:
            for policy in (ServableVersionPolicy(mode="canary"),
                           ServableVersionPolicy(mode="specific",
                                                 specific_version=1),
                           ServableVersionPolicy(mode="latest")):
                server.source.set_policy("clf", policy)
                server.refresh()
        finally:
            stop.set()
            [t.join(timeout=60) for t in ts]
        assert not errors, errors
        assert len(outs) >= 6
        for out in outs:
            assert out.shape == (1, 4)
            assert 0 <= out.min() and out.max() < CFG.vocab_size
        # transitions tore down the retired versions' engines
        live = set(server.prediction._engines)
        assert live <= {"clf@v2"} | {"clf@v1"}

    def test_inference_logging(self, server):
        server.predict("clf", batch(), batched=False)
        entries = server.inference_log.entries()
        assert entries and entries[-1]["method"] == "predict"
        assert entries[-1]["batch_size"] == 2

    def test_unload_frees_device_buffers(self, server):
        with server.manager.get_servable_handle("clf") as s:
            leaf = jax.tree_util.tree_leaves(s.params)[0]
        server.source.remove_servable("clf")
        server.refresh()
        assert server.available_models() == {}
        assert leaf.is_deleted()   # jax.Array.delete() ran on unload


class TestCheckpointRoundtrip:
    def test_save_load_exact(self, tmp_path):
        params = MD.init_params(jax.random.PRNGKey(0), CFG)
        save_checkpoint(str(tmp_path), "m", 1, params, {"arch": CFG.name})
        target = jax.eval_shape(
            lambda: MD.init_params(jax.random.PRNGKey(0), CFG))
        loaded = load_checkpoint(str(tmp_path / "m" / "1"), target)
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(loaded)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_loader_resource_estimate_matches_manifest(self, tmp_path):
        params = MD.init_params(jax.random.PRNGKey(0), CFG)
        save_checkpoint(str(tmp_path), "m", 1, params, {"arch": CFG.name})
        loader = JaxModelLoader(ServableId("m", 1),
                                str(tmp_path / "m" / "1"), cfg=CFG)
        est = loader.estimate_resources()
        nbytes = sum(np.asarray(l).nbytes
                     for l in jax.tree_util.tree_leaves(params))
        assert est.ram_bytes == int(nbytes * 1.1)
        servable = loader.load()
        assert isinstance(servable, JaxModelServable)
        out = servable.call("predict", batch())
        assert out.shape == (2, 16, CFG.vocab_size)
        servable.unload()

    def test_atomic_version_publish(self, tmp_path):
        """A half-written version dir must never be visible."""
        params = MD.init_params(jax.random.PRNGKey(0), CFG)
        path = save_checkpoint(str(tmp_path), "m", 7, params,
                               {"arch": CFG.name})
        assert os.path.basename(path) == "7"
        assert set(os.listdir(os.path.dirname(path))) == {"7"}
        assert {"params.npz", "manifest.json"} <= set(os.listdir(path))
