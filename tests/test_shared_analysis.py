"""Shared-state completeness analysis + Eraser-style lockset detector.

Static side: exact-diagnostic fixtures for every `repro.analysis.shared`
code (undeclared-shared, write-after-publish, bad-suppression,
bad-declaration) plus the clean shapes that must stay quiet.

Runtime side: unit tests for the lockset state machine (refinement,
common-lock quiet path, happens-before transfer, publish reset,
suppressed lines) driven through `racecheck.instrument_class` on
fixture classes compiled from the SAME source the static pass reads —
one set of declarations, two enforcers (mirrors
test_static_and_runtime_agree_on_abba for the lock-order pair).
"""
import textwrap
import threading

import pytest

from repro.analysis import instrumented, racecheck, shared
from repro.analysis.__main__ import run_all, run_shared


def diag_codes(src, path="mod.py"):
    return [d.code for d in
            shared.check_source_files([(path, textwrap.dedent(src))])]


def diags(src, path="mod.py"):
    return shared.check_source_files([(path, textwrap.dedent(src))])


# ---------------------------------------------------------------------------
# static completeness pass: one fixture per diagnostic code


class TestSharedDiagnostics:
    def test_undeclared_shared_thread_vs_client(self):
        """The canonical miss: a worker thread and the public surface
        both mutate an attribute nobody declared."""
        ds = diags("""\
            import threading

            class Worker:
                def __init__(self):
                    self._n = 0
                    self._lock = threading.Lock()

                def start(self):
                    threading.Thread(target=self._run).start()

                def _run(self):
                    self._n += 1

                def bump(self):
                    with self._lock:
                        pass
                    self._n += 1
            """)
        assert [d.code for d in ds] == ["undeclared-shared"]
        msg = ds[0].message
        assert "Worker._n" in msg
        # provenance: both thread-entry paths are named
        assert "client" in msg and "Worker._run" in msg

    def test_timer_callback_context_counts(self):
        ds = diags("""\
            import threading

            class Poller:
                def __init__(self):
                    self.ticks = 0
                    self._lock = threading.Lock()

                def arm(self):
                    threading.Timer(0.1, self._tick).start()

                def _tick(self):
                    self.ticks += 1

                def snapshot(self):
                    with self._lock:
                        pass
                    self.ticks = 0
            """)
        assert [d.code for d in ds] == ["undeclared-shared"]

    def test_guarded_declaration_silences(self):
        assert diag_codes("""\
            import threading

            class Worker:
                GUARDED_BY = {"_n": "_lock"}

                def __init__(self):
                    self._n = 0
                    self._lock = threading.Lock()

                def start(self):
                    threading.Thread(target=self._run).start()

                def _run(self):
                    with self._lock:
                        self._n += 1

                def bump(self):
                    with self._lock:
                        self._n += 1
            """) == []

    def test_shared_ok_with_reason_silences(self):
        assert diag_codes("""\
            import threading

            class Worker:
                def __init__(self):
                    self._lock = threading.Lock()
                    # shared-ok: transitions are mutually exclusive by design
                    self._n = 0

                def start(self):
                    threading.Thread(target=self._run).start()

                def _run(self):
                    self._n += 1

                def bump(self):
                    with self._lock:
                        pass
                    self._n += 1
            """) == []

    def test_bad_suppression_reason_is_mandatory(self):
        ds = diags("""\
            class C:
                def __init__(self):
                    # shared-ok:
                    self._x = 0
            """)
        assert [d.code for d in ds] == ["bad-suppression"]

    def test_write_after_publish(self):
        ds = diags("""\
            import threading

            class Server:
                def __init__(self):
                    self._lock = threading.Lock()
                    # published-by: start
                    self._t = None

                def start(self):
                    self._t = threading.Thread(target=self._loop)
                    self._t.start()

                def poke(self):
                    self._t = None

                def _loop(self):
                    pass
            """)
        assert [d.code for d in ds] == ["write-after-publish"]
        assert "poke" in ds[0].message

    def test_publisher_writes_are_legal(self):
        assert diag_codes("""\
            import threading

            class Server:
                def __init__(self):
                    self._lock = threading.Lock()
                    # published-by: start, stop
                    self._t = None

                def start(self):
                    self._t = threading.Thread(target=self._loop)
                    self._t.start()

                def stop(self):
                    self._t = None

                def _loop(self):
                    pass
            """) == []

    def test_bad_declaration_unknown_publisher(self):
        ds = diags("""\
            class Server:
                def __init__(self):
                    # published-by: nosuch
                    self._t = None

                def start(self):
                    self._t = object()
            """)
        codes = [d.code for d in ds]
        assert "bad-declaration" in codes
        assert any("nosuch" in d.message for d in ds)

    def test_sync_primitives_exempt(self):
        assert diag_codes("""\
            import threading

            class C:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._ev = threading.Event()

                def start(self):
                    threading.Thread(target=self._run).start()

                def _run(self):
                    self._ev = threading.Event()

                def reset(self):
                    with self._lock:
                        pass
                    self._ev = threading.Event()
            """) == []

    def test_immutable_after_init_quiet(self):
        assert diag_codes("""\
            import threading

            class C:
                def __init__(self):
                    self._cfg = {}
                    self._lock = threading.Lock()

                def start(self):
                    threading.Thread(target=self._run).start()

                def _run(self):
                    print(self._cfg)

                def peek(self):
                    with self._lock:
                        pass
                    return self._cfg
            """) == []


# ---------------------------------------------------------------------------
# runtime lockset detector: unit tests for the state machine


@pytest.fixture()
def racer():
    """Enable the detector for one test and sandbox its global state:
    deliberate violations here must never leak into a session-level
    REPRO_RACE_CHECK assertion, and plain runs must not stay patched."""
    was = racecheck.installed()
    lock_was = instrumented.installed()
    if not was:
        racecheck.install(modules=())
    with racecheck._mu:
        saved_log = list(racecheck._violation_log)
        saved_sites = dict(racecheck._sites)
    yield racecheck
    with racecheck._mu:
        racecheck._violation_log[:] = saved_log
        racecheck._sites.clear()
        racecheck._sites.update(saved_sites)
    if not was:
        racecheck.uninstall()
        if not lock_was:        # racecheck.install() chained this in
            instrumented.uninstall()


def _compile_fixture(src, path, clsname):
    """Build a fixture class from source so the runtime detector reads
    the SAME text the static pass would (co_filename/line provenance
    included), then instrument it."""
    src = textwrap.dedent(src)
    ns: dict = {}
    exec(compile(src, path, "exec"), ns)     # noqa: S102 — test fixture
    cls = ns[clsname]
    infos, suppressed = shared.runtime_class_info(src, path)
    racecheck.instrument_class(cls, infos[clsname], suppressed, path)
    return cls


COUNTER_SRC = """\
import threading

class Counter:
    GUARDED_BY = {"_n": "_lock"}

    def __init__(self, lock):
        self._lock = lock
        self._n = 0

    def locked_bump(self):
        with self._lock:
            self._n += 1

    def raw_bump(self):
        self._n += 1
"""


class TestLocksetDetector:
    def _shared_counter(self, cls, lock, *, use_lock_in_worker=True):
        """Return a Counter plus a parked worker thread that already
        touched ``_n`` (so the attribute is genuinely shared — the
        worker is alive and has no happens-before edge to later main-
        thread accesses)."""
        c = cls(lock)
        touched = threading.Event()
        release = threading.Event()

        def work():
            if use_lock_in_worker:
                c.locked_bump()
            else:
                c.raw_bump()
            touched.set()
            release.wait(5)

        t = threading.Thread(target=work, daemon=True)
        t.start()
        assert touched.wait(5)
        return c, t, release

    def test_empty_lockset_raises_with_both_stacks(self, racer, tmp_path):
        cls = _compile_fixture(COUNTER_SRC, str(tmp_path / "cnt.py"),
                               "Counter")
        try:
            c, t, release = self._shared_counter(
                cls, instrumented.InstrumentedLock(), use_lock_in_worker=False)
            with pytest.raises(racecheck.RaceViolation) as ei:
                c.raw_bump()            # no common lock: ∅ ∩ ∅
            release.set()
            t.join(5)
            msg = str(ei.value)
            assert "Counter._n" in msg
            assert "access 1" in msg and "access 2" in msg
            assert racer.violations()           # registry, not just raise
        finally:
            racecheck.deinstrument_class(cls)

    def test_common_lock_stays_quiet(self, racer, tmp_path):
        cls = _compile_fixture(COUNTER_SRC, str(tmp_path / "cnt2.py"),
                               "Counter")
        try:
            lock = instrumented.InstrumentedLock()
            c, t, release = self._shared_counter(cls, lock)
            c.locked_bump()             # same lock on every access
            c.locked_bump()
            with lock:                  # reads need it too
                n = c._n
            release.set()
            t.join(5)
            assert n == 3
        finally:
            racecheck.deinstrument_class(cls)

    def test_lockset_refinement_two_disjoint_locks(self, racer, tmp_path):
        """Each access IS locked — but never by the same lock. The
        candidate lockset initializes to the locks held at FIRST
        sharing, so the second thread's re-access under its own
        disjoint lock empties the intersection (classic Eraser)."""
        cls = _compile_fixture(COUNTER_SRC, str(tmp_path / "cnt3.py"),
                               "Counter")
        try:
            lock_a = instrumented.InstrumentedLock()
            c, t, release = self._shared_counter(cls, lock_a)
            other = instrumented.InstrumentedLock()
            with other:                 # first sharing: lockset = {other}
                c.raw_bump()
            with other:                 # refined: {other} ∩ {other} — quiet
                c.raw_bump()
            assert not racer.violations()
            with pytest.raises(racecheck.RaceViolation):
                c.locked_bump()         # {other} ∩ {lock_a} = ∅
            release.set()
            t.join(5)
            assert racer.violations()
        finally:
            racecheck.deinstrument_class(cls)

    def test_happens_before_transfer_stays_quiet(self, racer, tmp_path):
        """init-then-spawn then join-then-inspect: pure handoff, no
        lock anywhere, no violation — ownership transfers along the
        happens-before edges instead of escalating to Shared."""
        cls = _compile_fixture(COUNTER_SRC, str(tmp_path / "cnt4.py"),
                               "Counter")
        try:
            c = cls(instrumented.InstrumentedLock())
            c.raw_bump()                        # main owns
            t = threading.Thread(target=c.raw_bump)
            t.start()                           # child born after ^
            t.join(5)
            c.raw_bump()                        # owner thread is dead
            assert c._n == 3
            assert not racer.violations()
        finally:
            racecheck.deinstrument_class(cls)

    def test_publish_reset_reowns_attribute(self, racer, tmp_path):
        src = """\
        import threading

        class Box:
            def __init__(self):
                # published-by: flip
                self._v = 0

            def flip(self):
                self._v = 1

            def peek(self):
                return self._v
        """
        cls = _compile_fixture(src, str(tmp_path / "box.py"), "Box")
        try:
            b = cls()
            held = threading.Event()
            release = threading.Event()

            def reader():
                b.peek()
                held.set()
                release.wait(5)

            t = threading.Thread(target=reader, daemon=True)
            t.start()
            assert held.wait(5)
            # a write in a declared publisher re-enters Exclusive even
            # though the reader is alive and shares no lock
            b.flip()
            release.set()
            t.join(5)
            assert not racer.violations()
        finally:
            racecheck.deinstrument_class(cls)

    def test_unguarded_ok_lines_exempt(self, racer, tmp_path):
        src = """\
        import threading

        class Gauge:
            GUARDED_BY = {"_v": "_lock"}

            def __init__(self, lock):
                self._lock = lock
                self._v = 0

            def locked_set(self, v):
                with self._lock:
                    self._v = v

            def peek(self):
                return self._v  # unguarded-ok: snapshot read
        """
        cls = _compile_fixture(src, str(tmp_path / "gauge.py"), "Gauge")
        try:
            lock = instrumented.InstrumentedLock()
            g = cls(lock)
            seen = threading.Event()
            release = threading.Event()

            def work():
                g.locked_set(1)
                seen.set()
                release.wait(5)

            t = threading.Thread(target=work, daemon=True)
            t.start()
            assert seen.wait(5)
            assert g.peek() == 1        # lock-free but suppressed
            g.locked_set(2)             # still fine under the common lock
            release.set()
            t.join(5)
            assert not racer.violations()
        finally:
            racecheck.deinstrument_class(cls)


# ---------------------------------------------------------------------------
# static + runtime agree on the same seeded fixture


class TestStaticAndRuntimeAgree:
    SRC = """\
    import threading

    class Tally:
        def __init__(self, lock):
            self._lock = lock
            self._n = 0

        def start(self):
            threading.Thread(target=self._work).start()

        def _work(self):
            self._n += 1

        def bump(self):
            with self._lock:
                pass
            self._n += 1
    """

    DECLARED = SRC.replace(
        "class Tally:",
        'class Tally:\n        GUARDED_BY = {"_n": "_lock"}')

    def test_static_flags_undeclared(self):
        ds = shared.check_source_files(
            [("tally.py", textwrap.dedent(self.SRC))])
        assert [d.code for d in ds] == ["undeclared-shared"]
        assert "Tally._n" in ds[0].message

    def test_runtime_catches_the_same_race_once_declared(
            self, racer, tmp_path):
        """Declaring the attr satisfies the static pass — and hands it
        to the runtime detector, which catches the UNLOCKED access the
        declaration promised wouldn't happen. Same fixture, both nets."""
        src = textwrap.dedent(self.DECLARED)
        assert shared.check_source_files([("tally.py", src)]) == []
        cls = _compile_fixture(src, str(tmp_path / "tally.py"), "Tally")
        try:
            c = cls(instrumented.InstrumentedLock())
            touched = threading.Event()
            release = threading.Event()

            def work():
                c._n += 1               # worker writes without the lock
                touched.set()
                release.wait(5)

            t = threading.Thread(target=work, daemon=True)
            t.start()
            assert touched.wait(5)
            with pytest.raises(racecheck.RaceViolation):
                c.bump()                # bump's += is outside the lock
            release.set()
            t.join(5)
        finally:
            racecheck.deinstrument_class(cls)


# ---------------------------------------------------------------------------
# unified CLI


class TestUnifiedCli:
    def test_shared_cli_fails_on_seeded_fixture(self, tmp_path, capsys):
        bad = tmp_path / "bad.py"
        bad.write_text(textwrap.dedent("""\
            import threading

            class W:
                def __init__(self):
                    self._n = 0
                    self._lock = threading.Lock()

                def start(self):
                    threading.Thread(target=self._run).start()

                def _run(self):
                    self._n += 1

                def poke(self):
                    with self._lock:
                        pass
                    self._n += 1
            """))
        assert run_shared([str(bad)]) == 1
        out = capsys.readouterr().out
        assert "undeclared-shared" in out and "bad.py" in out

    def test_all_aggregates_and_fails_once(self, tmp_path, capsys):
        bad = tmp_path / "bad.py"
        bad.write_text(textwrap.dedent("""\
            class C:
                GUARDED_BY = {"_n": "_lock"}

                def bump(self):
                    self._n += 1
            """))
        assert run_all([str(bad)]) == 1
        cap = capsys.readouterr()
        assert "FAIL" in cap.err
        assert "unguarded-write" in cap.out

    def test_all_clean_tree_exits_zero(self, capsys):
        assert run_all(["src"]) == 0
        out = capsys.readouterr().out
        assert "shared=0" in out and "ok" in out
