"""Batched generation engine: correctness vs single-request generate,
wave bucketing, EOS stop, slot accounting."""
import threading

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as MD
from repro.serving.generation import GenerationEngine

CFG = get_config("tfs-classifier", smoke=True).with_overrides(
    dtype="float32")


@pytest.fixture(scope="module")
def engine():
    params = MD.init_params(jax.random.PRNGKey(0), CFG)
    eng = GenerationEngine(CFG, params, max_slots=4, max_prompt=32,
                           max_new=8)
    eng.start()
    yield eng
    eng.stop()


def reference_generate(engine, tokens, max_new):
    """Unbatched greedy reference through raw model calls."""
    params, cfg = engine.params, engine.cfg
    cache = MD.init_cache(cfg, 1, tokens.shape[0] + max_new)
    logits, cache = MD.prefill(params, cfg,
                               {"tokens": tokens[None]}, cache)
    out = [int(np.argmax(logits[0]))]
    for _ in range(max_new - 1):
        logits, cache = MD.decode_step(
            params, cfg, {"tokens": np.asarray([[out[-1]]])}, cache)
        out.append(int(np.argmax(logits[0])))
    return np.asarray(out, np.int32)


class TestGenerationEngine:
    def test_single_request_matches_reference(self, engine):
        rng = np.random.default_rng(0)
        toks = rng.integers(0, CFG.vocab_size, 16).astype(np.int32)
        got = engine.generate(toks, max_new=6)
        ref = reference_generate(engine, toks, 6)
        np.testing.assert_array_equal(got, ref)

    def test_concurrent_same_length_requests_batch_and_match(self,
                                                             engine):
        rng = np.random.default_rng(1)
        prompts = [rng.integers(0, CFG.vocab_size, 12).astype(np.int32)
                   for _ in range(4)]
        waves_before = engine.stats["waves"]
        results = [None] * 4

        def worker(i):
            results[i] = engine.generate(prompts[i], max_new=5)
        ts = [threading.Thread(target=worker, args=(i,))
              for i in range(4)]
        [t.start() for t in ts]
        [t.join() for t in ts]
        for i in range(4):
            ref = reference_generate(engine, prompts[i], 5)
            np.testing.assert_array_equal(results[i], ref)
        # batched into fewer waves than requests
        assert engine.stats["waves"] - waves_before < 4

    def test_mixed_lengths_bucketed_correctly(self, engine):
        rng = np.random.default_rng(2)
        p_a = rng.integers(0, CFG.vocab_size, 8).astype(np.int32)
        p_b = rng.integers(0, CFG.vocab_size, 20).astype(np.int32)
        results = {}

        def worker(key, p):
            results[key] = engine.generate(p, max_new=4)
        ts = [threading.Thread(target=worker, args=("a", p_a)),
              threading.Thread(target=worker, args=("b", p_b))]
        [t.start() for t in ts]
        [t.join() for t in ts]
        np.testing.assert_array_equal(
            results["a"], reference_generate(engine, p_a, 4))
        np.testing.assert_array_equal(
            results["b"], reference_generate(engine, p_b, 4))

    def test_eos_stops_early(self):
        params = MD.init_params(jax.random.PRNGKey(0), CFG)
        eng = GenerationEngine(CFG, params, max_slots=2, max_new=8)
        # find the first generated token and use it as EOS
        eng.start()
        try:
            toks = np.arange(10, dtype=np.int32)
            full = eng.generate(toks, max_new=8)
            eng.eos = int(full[1])
            out = eng.generate(toks, max_new=8)
            assert out.shape[0] <= 2 or eng.eos not in out[:-1]
        finally:
            eng.stop()
