"""Tests for the concurrency static analysis + runtime lock validator.

Covers the AST guarded-by checker (exact diagnostics on seeded
violations, clean fixtures, suppressions), the static lock-order cycle
pass, the instrumented-lock runtime validator (the same ABBA fixture
must be caught by BOTH), the wall-clock lint, and a smoke test that the
real batching components run clean under instrumentation.
"""
import threading
import time
import textwrap

import numpy as np
import pytest

from repro.analysis import guarded, instrumented, lockorder, locks_required
from repro.analysis.__main__ import run_check


def check(src, path="mod.py", **kw):
    return guarded.check_source(textwrap.dedent(src), path, **kw)


def cycles(src, path="mod.py"):
    return lockorder.check_lockorder([(path, textwrap.dedent(src))])


# ---------------------------------------------------------------------------
# guarded-by checker


class TestGuardedChecker:
    def test_unguarded_read_exact_diagnostic(self):
        diags = check("""\
            class C:
                GUARDED_BY = {"_items": "_lock"}

                def size(self):
                    return len(self._items)
            """)
        assert len(diags) == 1
        d = diags[0]
        assert (d.path, d.line, d.code) == ("mod.py", 5, "unguarded-read")
        assert "C._items" in d.message and "self._lock" in d.message
        assert str(d) == f"mod.py:5: [{d.code}] {d.message}"

    def test_unguarded_write_flagged(self):
        diags = check("""\
            class C:
                GUARDED_BY = {"_n": "_lock"}

                def bump(self):
                    self._n += 1
            """)
        assert [d.code for d in diags] == ["unguarded-write"]

    def test_locked_access_is_clean(self):
        assert check("""\
            class C:
                GUARDED_BY = {"_items": "_lock"}

                def size(self):
                    with self._lock:
                        return len(self._items)
            """) == []

    def test_init_is_exempt(self):
        assert check("""\
            class C:
                GUARDED_BY = {"_items": "_lock"}

                def __init__(self):
                    self._items = []
            """) == []

    def test_locks_required_method_and_call_sites(self):
        diags = check("""\
            class C:
                GUARDED_BY = {"_items": "_lock"}

                @locks_required("_lock")
                def _drain(self):
                    self._items.clear()

                def good(self):
                    with self._lock:
                        self._drain()

                def bad(self):
                    self._drain()
            """)
        assert [d.code for d in diags] == ["lock-required-call"]
        assert diags[0].line == 13
        assert "self._lock" in diags[0].message

    def test_inline_guarded_by_comment(self):
        diags = check("""\
            class C:
                def __init__(self):
                    self._q = []   # guarded-by: self._mu

                def peek(self):
                    return self._q[0]
            """)
        assert [d.code for d in diags] == ["unguarded-read"]

    def test_suppression_with_reason(self):
        assert check("""\
            class C:
                GUARDED_BY = {"_items": "_lock"}

                def size(self):
                    # unguarded-ok: snapshot read of an immutable list
                    return len(self._items)
            """) == []

    def test_suppression_without_reason_is_rejected(self):
        diags = check("""\
            class C:
                GUARDED_BY = {"_items": "_lock"}

                def size(self):
                    return len(self._items)  # unguarded-ok:
            """)
        assert "bad-suppression" in {d.code for d in diags}

    def test_nested_def_checked_with_empty_held_set(self):
        # The with-block lock does NOT cover a nested def: it runs
        # later, on an unknown thread.
        diags = check("""\
            class C:
                GUARDED_BY = {"_items": "_lock"}

                def make(self):
                    with self._lock:
                        def cb():
                            return self._items
                        return cb
            """)
        assert [d.code for d in diags] == ["unguarded-read"]

    def test_other_objects_attrs_unchecked(self):
        assert check("""\
            class C:
                GUARDED_BY = {"_items": "_lock"}

                def peek(self, other):
                    return other._items
            """) == []

    def test_bad_guarded_by_declaration(self):
        diags = check("""\
            class C:
                GUARDED_BY = ["_items"]
            """)
        assert [d.code for d in diags] == ["bad-declaration"]


class TestWallClockLint:
    def test_bare_time_time_flagged_only_when_enabled(self):
        src = """\
            import time

            def stamp():
                return time.time()
            """
        assert check(src) == []
        diags = check(src, wallclock=True)
        assert [d.code for d in diags] == ["wall-clock"]
        assert diags[0].line == 4

    def test_wall_clock_ok_suppresses(self):
        assert check("""\
            import time

            def stamp():
                # wall-clock-ok: trace-replay timestamp
                return time.time()
            """, wallclock=True) == []

    def test_monotonic_is_fine(self):
        assert check("""\
            import time

            def stamp():
                return time.monotonic()
            """, wallclock=True) == []


# ---------------------------------------------------------------------------
# static lock-order analysis


class TestLockOrder:
    def test_cross_class_abba_cycle(self):
        src = """\
            import threading

            class A:
                def __init__(self, b: "B"):
                    self._la = threading.Lock()
                    self.b = b

                def ab(self):
                    with self._la:
                        self.b.take()

                def take(self):
                    with self._la:
                        pass

            class B:
                def __init__(self, a: "A"):
                    self._lb = threading.Lock()
                    self.a = a

                def ba(self):
                    with self._lb:
                        self.a.take()

                def take(self):
                    with self._lb:
                        pass
            """
        diags = cycles(src)
        assert [d.code for d in diags] == ["lock-cycle"]
        msg = diags[0].message
        assert "A._la -> B._lb" in msg and "B._lb -> A._la" in msg
        assert "mod.py:" in msg        # every hop carries provenance

    def test_same_class_nested_with_cycle(self):
        diags = cycles("""\
            import threading

            class C:
                def __init__(self):
                    self._a = threading.Lock()
                    self._b = threading.Lock()

                def ab(self):
                    with self._a:
                        with self._b:
                            pass

                def ba(self):
                    with self._b:
                        with self._a:
                            pass
            """)
        assert [d.code for d in diags] == ["lock-cycle"]

    def test_consistent_order_is_clean(self):
        assert cycles("""\
            import threading

            class C:
                def __init__(self):
                    self._a = threading.Lock()
                    self._b = threading.Lock()

                def ab(self):
                    with self._a:
                        with self._b:
                            pass

                def also_ab(self):
                    with self._a:
                        with self._b:
                            pass
            """) == []

    def test_condition_alias_is_same_node(self):
        # Condition(self._mutex) aliases _idle to _mutex; nesting them
        # is a legal re-entry, not a 2-cycle.
        assert cycles("""\
            import threading

            class C:
                def __init__(self):
                    self._mutex = threading.RLock()
                    self._idle = threading.Condition(self._mutex)

                def work(self):
                    with self._mutex:
                        with self._idle:
                            pass
            """) == []

    def test_self_edge_on_plain_lock(self):
        diags = cycles("""\
            import threading

            class C:
                def __init__(self):
                    self._lock = threading.Lock()

                def outer(self):
                    with self._lock:
                        self.inner()

                def inner(self):
                    with self._lock:
                        pass
            """)
        assert [d.code for d in diags] == ["lock-cycle"]

    def test_rlock_reentry_is_legal(self):
        assert cycles("""\
            import threading

            class C:
                def __init__(self):
                    self._lock = threading.RLock()

                def outer(self):
                    with self._lock:
                        self.inner()

                def inner(self):
                    with self._lock:
                        pass
            """) == []

    def test_repo_hot_paths_are_acyclic(self):
        assert run_check(["src"], no_lockorder=False) == 0


# ---------------------------------------------------------------------------
# runtime validator


@pytest.fixture()
def runtime():
    """Snapshot/restore the global order graph + violation registry so
    deliberate violations here never leak into the session-level
    REPRO_LOCK_CHECK assertion (or other tests)."""
    with instrumented._graph_mu:
        saved_log = list(instrumented._violation_log)
        saved_succ = {k: set(v) for k, v in instrumented._succ.items()}
        saved_cont = {k: list(v) for k, v in instrumented._contention.items()}
    yield instrumented
    with instrumented._graph_mu:
        instrumented._violation_log[:] = saved_log
        instrumented._succ.clear()
        instrumented._succ.update(saved_succ)
        instrumented._contention.clear()
        instrumented._contention.update(saved_cont)


class TestInstrumentedLocks:
    def test_abba_caught_without_deadlocking(self, runtime):
        a = instrumented.InstrumentedLock()
        b = instrumented.InstrumentedLock()
        with a:
            with b:                       # observes A -> B
                pass
        before = len(runtime.violations())
        with b:
            with pytest.raises(instrumented.LockOrderViolation):
                a.acquire()               # B -> A inverts it
        assert len(runtime.violations()) == before + 1
        assert "inversion" in runtime.violations()[-1]

    def test_consistent_order_never_raises(self, runtime):
        a = instrumented.InstrumentedLock()
        b = instrumented.InstrumentedLock()
        for _ in range(3):
            with a:
                with b:
                    pass

    def test_self_deadlock_detected_not_hung(self, runtime):
        lk = instrumented.InstrumentedLock()
        with lk:
            with pytest.raises(instrumented.LockOrderViolation):
                lk.acquire()              # would block forever un-instrumented

    def test_rlock_reentry_fine(self, runtime):
        lk = instrumented.InstrumentedRLock()
        with lk:
            with lk:
                pass
        assert lk.locked() is False       # fully released

    def test_hold_time_violation(self, runtime, monkeypatch):
        monkeypatch.setenv("REPRO_LOCK_HOLD_S", "0.01")
        lk = instrumented.InstrumentedLock()
        lk.acquire()
        time.sleep(0.05)
        with pytest.raises(instrumented.HoldTimeViolation):
            lk.release()
        assert not lk.locked()            # raw lock still released

    def test_condition_wait_releases_held_entry(self, runtime):
        cond = instrumented.InstrumentedCondition()
        with cond:
            # wait() must drop the lock from the held set (and re-note
            # it on wake) or the timeout re-acquire would self-trip.
            assert cond.wait(timeout=0.01) is False
            assert cond.wait_for(lambda: False, timeout=0.01) is False

    def test_cross_thread_abba(self, runtime):
        """The canonical two-thread ABBA: thread 1 teaches A -> B, the
        main thread then tries B -> A and is stopped at acquire time —
        no deadlock interleaving required."""
        a = instrumented.InstrumentedLock()
        b = instrumented.InstrumentedLock()

        def t1():
            with a:
                with b:
                    pass

        th = threading.Thread(target=t1)
        th.start()
        th.join()
        with b:
            with pytest.raises(instrumented.LockOrderViolation):
                with a:
                    pass


class TestInstallation:
    def test_install_uninstall_roundtrip(self):
        was = instrumented.installed()
        instrumented.install()
        try:
            assert instrumented.installed()
            # Locks created from NON-repro modules (this test) stay raw.
            assert not isinstance(threading.Lock(),
                                  instrumented._InstrumentedBase)
        finally:
            if not was:
                instrumented.uninstall()
        assert instrumented.installed() == was

    def test_repro_components_clean_under_instrumentation(self, runtime):
        """Smoke: the real batching pipeline runs with instrumented
        locks and records zero violations."""
        from repro.batching import (BatchingOptions, BatchingSession,
                                    SharedBatchScheduler)

        was = instrumented.installed()
        instrumented.install()
        try:
            before = len(runtime.violations())
            sched = SharedBatchScheduler()
            sched.start()
            try:
                sess = BatchingSession(
                    "m", lambda x: x * 2, sched,
                    BatchingOptions(max_batch_size=8,
                                    batch_timeout_s=0.005))
                outs = [None] * 6

                def worker(i):
                    outs[i] = sess.run(np.full((1, 2), float(i)))

                ts = [threading.Thread(target=worker, args=(i,))
                      for i in range(6)]
                [t.start() for t in ts]
                [t.join() for t in ts]
                for i in range(6):
                    assert np.allclose(outs[i], 2.0 * i)
                sess.close()
            finally:
                sched.stop()
            assert runtime.violations()[before:] == []
        finally:
            if not was:
                instrumented.uninstall()


# ---------------------------------------------------------------------------
# decorator + CLI


class TestDecorator:
    def test_locks_required_is_zero_cost(self):
        @locks_required("_lock", "self._other")
        def fn(self):
            return 42

        assert fn.__locks_required__ == ("_lock", "self._other")
        assert fn(None) == 42

    def test_locks_required_validates(self):
        with pytest.raises(ValueError):
            locks_required()
        with pytest.raises(ValueError):
            locks_required(42)


class TestCli:
    def test_check_fails_on_seeded_violation(self, tmp_path, capsys):
        bad = tmp_path / "bad.py"
        bad.write_text(textwrap.dedent("""\
            class C:
                GUARDED_BY = {"_n": "_lock"}

                def bump(self):
                    self._n += 1
            """))
        assert run_check([str(bad)]) == 1
        out = capsys.readouterr().out
        assert "unguarded-write" in out and "bad.py:5" in out

    def test_check_passes_clean_file(self, tmp_path, capsys):
        good = tmp_path / "good.py"
        good.write_text(textwrap.dedent("""\
            class C:
                GUARDED_BY = {"_n": "_lock"}

                def bump(self):
                    with self._lock:
                        self._n += 1
            """))
        assert run_check([str(good)]) == 0
        assert "clean" in capsys.readouterr().out

    def test_static_and_runtime_agree_on_abba(self, tmp_path, runtime):
        """The same ABBA shape is caught by BOTH validators."""
        src = textwrap.dedent("""\
            import threading

            class P:
                def __init__(self, q: "Q"):
                    self._lp = threading.Lock()
                    self.q = q

                def go(self):
                    with self._lp:
                        self.q.touch()

                def touch(self):
                    with self._lp:
                        pass

            class Q:
                def __init__(self, p: "P"):
                    self._lq = threading.Lock()
                    self.p = p

                def go(self):
                    with self._lq:
                        self.p.touch()

                def touch(self):
                    with self._lq:
                        pass
            """)
        static = lockorder.check_lockorder([("abba.py", src)])
        assert [d.code for d in static] == ["lock-cycle"]

        lp = instrumented.InstrumentedLock()
        lq = instrumented.InstrumentedLock()
        with lp:
            with lq:
                pass
        with lq:
            with pytest.raises(instrumented.LockOrderViolation):
                lp.acquire()


# ---------------------------------------------------------------------------
# callback-carried lock-order edges


class TestCallbackLockOrder:
    # The PR-8 follow-on: a lock acquired inside a *callback* must
    # contribute ordering edges at every dispatch site the callback may
    # run from — the manager/event-bus shape where the inversion hides
    # behind a function-valued attribute.
    ABBA = """\
        import threading
        from typing import Callable


        class Notifier:
            def __init__(self):
                self._mu_b = threading.Lock()
                self._subs: list = []

            def subscribe(self, fn: Callable[[], None]) -> None:
                self._subs.append(fn)

            def fire(self) -> None:
                with self._mu_b:
                    for cb in list(self._subs):
                        cb()


        class Listener:
            def __init__(self, notifier: Notifier):
                self._mu_a = threading.Lock()
                self.notifier = notifier
                notifier.subscribe(self._on_event)

            def _on_event(self) -> None:
                with self._mu_a:
                    pass

            def poke(self) -> None:
                with self._mu_a:
                    self.notifier.fire()
        """

    def test_callback_abba_cycle_detected(self):
        diags = cycles(self.ABBA)
        assert diags and all(d.code == "lock-cycle" for d in diags)
        msgs = " ; ".join(d.message for d in diags)
        # The callback-carried edge: fire() holds _mu_b while the pooled
        # listener callback acquires _mu_a...
        assert "Notifier._mu_b -> Listener._mu_a" in msgs
        # ...inverting poke()'s _mu_a-held call into fire().
        assert "Listener._mu_a -> Notifier._mu_b" in msgs

    def test_dispatch_outside_locks_is_clean(self):
        # Snapshot-then-dispatch on both sides breaks every edge.
        safe = self.ABBA.replace("""\
                with self._mu_b:
                    for cb in list(self._subs):
                        cb()
""", """\
                with self._mu_b:
                    subs = list(self._subs)
                for cb in subs:
                    cb()
""").replace("""\
                with self._mu_a:
                    self.notifier.fire()
""", """\
                with self._mu_a:
                    pass
                self.notifier.fire()
""")
        assert cycles(safe) == []


# ---------------------------------------------------------------------------
# lock-contention sampling


class TestContentionSampling:
    def test_report_ranks_waiting_sites(self, runtime):
        lk = instrumented.InstrumentedLock()
        lk.acquire()
        t = threading.Thread(target=lambda: (lk.acquire(), lk.release()))
        t.start()
        time.sleep(0.05)            # the thread blocks in acquire()
        lk.release()
        t.join()
        row = next(r for r in instrumented.contention_report()
                   if r["site"] == lk._site)
        assert row["acquires"] >= 2
        assert row["total_wait_s"] >= 0.03
        assert 0 < row["max_wait_s"] <= row["total_wait_s"]

    def test_top_n_and_reset(self, runtime):
        lk = instrumented.InstrumentedLock()
        with lk:
            pass
        assert len(instrumented.contention_report(top=1)) == 1
        instrumented.reset()
        assert instrumented.contention_report() == []
