"""HTTP/JSON transport: wire-codec round trips (property-based),
error-taxonomy -> status-code mapping for all four codes, streamed
generate bit-identical to blocking over a real socket, client
disconnect mid-stream freeing decode-engine KV blocks, and graceful
drain (in-flight finishes, drain-time arrivals get 503)."""
import json
import os
import threading
import time
from http.client import HTTPConnection

import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # optional dep

from repro.configs import get_config
from repro.core import (AspiredVersion, AspiredVersionsManager,
                        CallableLoader, ResourceEstimate)
from repro.core.servable import Servable, ServableId
from repro.models import model as MD
from repro.serving import api, wire
from repro.serving.generation import SamplingParams
from repro.serving.server import ModelServer
from repro.serving.transport import (STATUS_FOR_CODE, HttpServingServer,
                                     ServingClient)
from repro.training.checkpoint import save_checkpoint

CFG = get_config("tfs-classifier", smoke=True)


def round_trip(value):
    """Encode -> actual JSON text -> decode (exactly what the socket
    carries)."""
    return wire.decode_value(json.loads(json.dumps(
        wire.encode_value(value))))


class TestWireCodec:
    @pytest.mark.parametrize("dtype", [
        "<f2", "<f4", "<f8", "<i4", "<i8", "<u2", "|u1", "|b1", "<c8",
        "<c16", "<U7"])
    @pytest.mark.parametrize("shape", [(), (0,), (3,), (2, 3), (0, 4)])
    def test_ndarray_exact(self, dtype, shape):
        n = int(np.prod(shape, dtype=int))
        if dtype == "<U7":
            flat = np.array(["héllo", "wörld✓", "", "日本語"] * (n + 1),
                            dtype=dtype)[:n]
        else:
            flat = (np.arange(n) % 5).astype(dtype)
        arr = flat.reshape(shape)
        out = round_trip(arr)
        assert isinstance(out, np.ndarray)
        assert out.dtype == arr.dtype and out.shape == arr.shape
        assert out.tobytes() == arr.tobytes()       # bit-identical

    def test_extension_dtype_bfloat16(self):
        import ml_dtypes
        arr = np.arange(6, dtype=np.float32).astype(
            ml_dtypes.bfloat16).reshape(2, 3)
        out = round_trip(arr)
        assert out.dtype == arr.dtype
        assert out.tobytes() == arr.tobytes()

    def test_object_dtype_rejected(self):
        with pytest.raises(wire.WireError):
            wire.encode_value(np.array([object()]))
        assert issubclass(wire.WireError, api.InvalidArgument)

    def test_tuple_and_tag_escape(self):
        v = {"__wire__": "sneaky", "t": (1, ("a", None)), "s": "ünï"}
        out = round_trip(v)
        assert out == v and isinstance(out["t"], tuple)

    def test_registered_dataclasses(self):
        reqs = [
            api.ModelSpec("clf", label="canary"),
            api.GenerateRequest(api.ModelSpec("m"),
                                tokens=np.arange(4, dtype=np.int32),
                                sampling=SamplingParams(0.7, 5, 3),
                                stream=True),
            api.TokenChunk(7, 0, False),
        ]
        for req in reqs:
            out = round_trip(req)
            assert type(out) is type(req)
        out = round_trip(reqs[1])
        np.testing.assert_array_equal(out.tokens, reqs[1].tokens)
        assert out.sampling == reqs[1].sampling

    def test_unregistered_dataclass_rejected(self):
        import dataclasses

        @dataclasses.dataclass
        class Evil:
            x: int = 0

        with pytest.raises(wire.WireError):
            wire.encode_value(Evil())
        with pytest.raises(wire.WireError):
            wire.decode_value({"__wire__": "dc", "type": "Evil",
                               "fields": {"x": 1}})

    @given(st.recursive(
        st.one_of(st.none(), st.booleans(), st.integers(-2**31, 2**31),
                  st.floats(allow_nan=False, allow_infinity=False),
                  st.text(max_size=12)),
        lambda c: st.one_of(
            st.lists(c, max_size=4), st.tuples(c, c),
            st.dictionaries(st.text(max_size=8), c, max_size=4)),
        max_leaves=12))
    @settings(max_examples=120, deadline=None)
    def test_value_round_trip_property(self, value):
        assert round_trip(value) == value

    @given(st.lists(st.text(max_size=6), max_size=5),
           st.sampled_from(["<f4", "<i8", "|b1", "<c8"]),
           st.lists(st.integers(0, 3), max_size=3))
    @settings(max_examples=60, deadline=None)
    def test_ndarray_round_trip_property(self, strings, dtype, shape):
        n = int(np.prod(shape, dtype=int))
        arr = (np.arange(n) % 3).astype(dtype).reshape(shape)
        uni = np.array(strings, dtype="<U8")
        for a in (arr, uni):
            out = round_trip(a)
            assert out.dtype == a.dtype and out.shape == a.shape
            assert out.tobytes() == a.tobytes()

    def test_message_plain_json_and_unknown_fields(self):
        # curl-style: plain nested lists for tensors, plain dicts for
        # nested messages
        req = wire.decode_message(api.PredictRequest, {
            "model_spec": {"name": "clf", "version": 2},
            "inputs": {"tokens": [[1, 2], [3, 4]]}, "batched": False})
        assert req.model_spec == api.ModelSpec("clf", 2)
        assert isinstance(req.inputs["tokens"], np.ndarray)
        with pytest.raises(wire.WireError):
            wire.decode_message(api.PredictRequest,
                                {"model_sepc": {"name": "clf"}})

    def test_message_round_trip_typed(self):
        resp = api.GetModelStatusResponse(
            api.ModelSpec("clf"),
            (api.ModelVersionStatus(1, "READY"),
             api.ModelVersionStatus(2, "LOADING", "boom")),
            {"stable": 1})
        out = wire.decode_message(
            api.GetModelStatusResponse,
            json.loads(json.dumps(wire.encode_message(resp))))
        assert out == resp and isinstance(out.versions, tuple)


# ---------------------------------------------------------------------------
# Live server fixtures
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def stack(tmp_path_factory):
    tmp = tmp_path_factory.mktemp("models")
    for v in (1, 2):
        params = MD.init_params(jax.random.PRNGKey(v), CFG)
        save_checkpoint(str(tmp), "clf", v, params, {"arch": CFG.name})
    srv = ModelServer({"clf": os.path.join(str(tmp), "clf")},
                      cfg_for=lambda n: CFG)
    srv.start_sync()
    http = srv.serve_http()
    client = ServingClient(*http.address)
    yield srv, http, client
    client.close()
    http.stop()
    srv.stop()


def batch(b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    return {"tokens": rng.integers(0, CFG.vocab_size, (b, s))}


def raw_post(addr, path, payload):
    conn = HTTPConnection(*addr)
    try:
        conn.request("POST", path, json.dumps(payload).encode("utf-8"),
                     {"Content-Type": "application/json"})
        resp = conn.getresponse()
        return resp.status, json.loads(resp.read())
    finally:
        conn.close()


class TestOverTheWire:
    def test_predict_bit_identical(self, stack):
        srv, _, client = stack
        b = batch()
        resp = client.predict(api.PredictRequest(
            api.ModelSpec("clf"), b, batched=False))
        ref = srv.predict("clf", b, batched=False)
        assert resp.model_spec == api.ModelSpec("clf", 2)
        assert resp.outputs.dtype == ref.dtype
        assert resp.outputs.tobytes() == ref.tobytes()

    def test_generic_call_and_multi_inference(self, stack):
        srv, _, client = stack
        b = batch()
        out = client.call(api.ModelSpec("clf"), "predict", b)
        assert out.tobytes() == srv.predict("clf", b,
                                            batched=False).tobytes()
        mi = client.multi_inference(api.MultiInferenceRequest(
            api.ModelSpec("clf"), b, k=3))
        ref = srv.multi_inference("clf", b, k=3)
        np.testing.assert_array_equal(mi.classify.classes,
                                      ref.classify.classes)
        np.testing.assert_array_equal(mi.regress.values.astype(
            np.float32), ref.regress.values.astype(np.float32))

    def test_model_status_and_labels(self, stack):
        srv, _, client = stack
        st_ = client.get_model_status(api.GetModelStatusRequest(
            api.ModelSpec("clf")))
        assert {v.version: v.state for v in st_.versions} == {
            1: "DISABLED", 2: "READY"} or all(
            v.state == "READY" for v in st_.versions)
        client.set_version_labels("clf", {"prod": 2})
        assert srv.manager.version_labels("clf")["prod"] == 2
        resp = client.predict(api.PredictRequest(
            api.ModelSpec("clf", label="prod"), batch(), batched=False))
        assert resp.model_spec.version == 2
        client.set_version_labels("clf", {"prod": None})

    def test_status_codes_all_four(self, stack):
        """NOT_FOUND->404, INVALID_ARGUMENT->400,
        FAILED_PRECONDITION->412 here; UNAVAILABLE->503 asserted in
        TestDrain (same map, real drain)."""
        _, http, client = stack
        addr = http.address
        status, body = raw_post(addr, "/v1/predict", {
            "model_spec": {"name": "ghost"}, "inputs": {}})
        assert (status, body["error"]["code"]) == (404, "NOT_FOUND")
        status, body = raw_post(addr, "/v1/predict", {
            "model_spec": {"name": "clf", "version": 1,
                           "label": "stable"}, "inputs": {}})
        assert (status, body["error"]["code"]) == (400,
                                                   "INVALID_ARGUMENT")
        status, body = raw_post(addr, "/v1/set_version_labels", {
            "name": "clf", "labels": {"prod": 99}})
        assert (status, body["error"]["code"]) == (412,
                                                   "FAILED_PRECONDITION")
        assert STATUS_FOR_CODE["UNAVAILABLE"] == 503
        # and the client maps them back into the typed taxonomy
        with pytest.raises(api.NotFound):
            client.predict(api.PredictRequest(api.ModelSpec("ghost"),
                                              batch(), batched=False))
        with pytest.raises(api.InvalidArgument):
            client.predict(api.PredictRequest(
                api.ModelSpec("clf", 1, "stable"), batch(),
                batched=False))
        with pytest.raises(api.FailedPrecondition):
            client.set_version_labels("clf", {"prod": 99})

    def test_malformed_body_and_unknown_route(self, stack):
        _, http, _ = stack
        addr = http.address
        status, body = raw_post(addr, "/v1/predict",
                                {"model_sepc": {"name": "clf"}})
        assert (status, body["error"]["code"]) == (400,
                                                   "INVALID_ARGUMENT")
        status, body = raw_post(addr, "/v1/frobnicate", {})
        assert status == 404

    def test_reload_config_over_wire(self, stack, tmp_path):
        srv, _, client = stack
        params = MD.init_params(jax.random.PRNGKey(7), CFG)
        save_checkpoint(str(tmp_path), "m2", 1, params,
                        {"arch": CFG.name})
        clf_dir = srv.source.current_config()["clf"][0]
        resp = client.reload_config(api.ReloadConfigRequest({
            "clf": api.ModelDirConfig(clf_dir),
            "m2": api.ModelDirConfig(os.path.join(str(tmp_path), "m2"))}))
        assert resp.added == ("m2",)
        out = client.predict(api.PredictRequest(
            api.ModelSpec("m2"), batch(), batched=False))
        assert out.model_spec == api.ModelSpec("m2", 1)
        resp = client.reload_config(api.ReloadConfigRequest(
            {"clf": api.ModelDirConfig(clf_dir)}))
        assert resp.removed == ("m2",)
        with pytest.raises(api.NotFound):
            client.predict(api.PredictRequest(api.ModelSpec("m2"),
                                              batch(), batched=False))


class TestStreamingOverTheWire:
    def test_stream_concat_bit_identical_to_blocking(self, stack):
        srv, _, client = stack
        toks = batch(b=1, s=12, seed=3)["tokens"][0].astype(np.int32)
        blocking = srv.generate("clf", tokens=toks, max_new=6)
        chunks = list(client.generate(api.GenerateRequest(
            api.ModelSpec("clf"), tokens=toks, max_new=6, stream=True)))
        assert len(chunks) == 6
        assert [c.index for c in chunks] == list(range(6))
        assert all(not c.final for c in chunks[:-1]) and chunks[-1].final
        np.testing.assert_array_equal(
            np.asarray([c.token for c in chunks], np.int32), blocking[0])
        wire_blocking = client.generate(api.GenerateRequest(
            api.ModelSpec("clf"), tokens=toks, max_new=6))
        np.testing.assert_array_equal(wire_blocking.tokens, blocking)

    def test_disconnect_mid_stream_frees_engine_blocks(self, stack):
        """A client that hangs up mid-stream must cancel the decode
        request: the slot retires and every paged KV block returns to
        the free list (asserted via engine stats)."""
        srv, _, client = stack
        toks = batch(b=1, s=8, seed=4)["tokens"][0].astype(np.int32)
        # ensure the engine exists and note its quiescent state
        srv.generate("clf", tokens=toks, max_new=2)
        eng = srv.prediction._engines["clf@v2"]
        cancelled0 = eng.stats["cancelled"]
        it = client.generate(api.GenerateRequest(
            api.ModelSpec("clf"), tokens=toks, max_new=400, stream=True))
        got = [next(it) for _ in range(2)]
        assert len(got) == 2
        it.close()                          # socket closes -> disconnect
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            if (eng.stats["cancelled"] > cancelled0
                    and eng.free_block_count() == eng.num_blocks - 1
                    and eng.active_slots() == 0):
                break
            time.sleep(0.02)
        assert eng.stats["cancelled"] > cancelled0
        assert eng.free_block_count() == eng.num_blocks - 1
        assert eng.active_slots() == 0

    def test_stream_invalid_request_is_typed(self, stack):
        _, _, client = stack
        with pytest.raises(api.InvalidArgument):
            client.generate(api.GenerateRequest(
                api.ModelSpec("clf"), tokens=batch()["tokens"],
                max_new=4, stream=True))
        with pytest.raises(api.NotFound):
            client.generate(api.GenerateRequest(
                api.ModelSpec("ghost"),
                tokens=np.arange(4, dtype=np.int32), max_new=4,
                stream=True))


# ---------------------------------------------------------------------------
# Graceful drain (lightweight servables; no JAX)
# ---------------------------------------------------------------------------


class _SlowServable(Servable):
    def call(self, method, request):
        if method == "oserror":
            raise OSError("backing store went away")
        time.sleep(float(request.get("delay", 0)))
        return {"served": True}


@pytest.fixture()
def slow_server():
    sid = ServableId("slow", 1)
    manager = AspiredVersionsManager()
    manager.set_aspired_versions("slow", [AspiredVersion(
        sid, CallableLoader(sid, lambda: _SlowServable(sid),
                            ResourceEstimate(ram_bytes=1)))])
    assert manager.await_idle()
    ps = api.PredictionService(manager)
    http = HttpServingServer(ps, drain_timeout_s=30).start()
    client = ServingClient(*http.address)
    yield http, client
    client.close()
    http.stop()
    manager.shutdown()


class TestServerRobustness:
    def test_service_oserror_is_500_not_disconnect(self, slow_server):
        """An OSError raised by SERVICE code must come back as a real
        500 response — not be mistaken for a client disconnect and
        silently dropped (which would make the client retry blindly)."""
        http, _ = slow_server
        status, body = raw_post(http.address, "/v1/call", {
            "model_spec": {"name": "slow"}, "method": "oserror",
            "request": {}})
        assert status == 500
        assert body["error"]["code"] == "UNKNOWN"
        assert "backing store" in body["error"]["message"]

    def test_error_paths_keep_keepalive_in_sync(self, slow_server):
        """Error responses must still drain the request body: the next
        request on the same keep-alive connection has to parse cleanly
        (leftover body bytes would desync the framing)."""
        http, _ = slow_server
        conn = HTTPConnection(*http.address)
        try:
            for path in ("/v1/no_such_route", "/v1/reload_config"):
                # /v1/reload_config raises FailedPrecondition (no
                # ModelService here) BEFORE the body would be parsed
                conn.request("POST", path,
                             json.dumps({"junk": "x" * 4096}).encode(),
                             {"Content-Type": "application/json"})
                resp = conn.getresponse()
                assert resp.status in (404, 412)
                resp.read()
                # same connection, next request must still work
                conn.request("POST", "/v1/call", json.dumps({
                    "model_spec": {"name": "slow"}, "method": "work",
                    "request": {"delay": 0}}).encode(),
                    {"Content-Type": "application/json"})
                resp = conn.getresponse()
                assert resp.status == 200
                assert json.loads(resp.read())["result"] == {
                    "served": True}
        finally:
            conn.close()


class TestDrain:
    def test_inflight_finishes_new_requests_503(self, slow_server):
        http, client = slow_server
        addr = http.address
        results, errors = [], []

        def inflight():
            try:
                results.append(client.call(api.ModelSpec("slow"), "work",
                                           {"delay": 1.0}))
            except Exception as exc:            # any failure is the bug
                errors.append(exc)

        # Peek server counters under its lock: these attrs are
        # GUARDED_BY _lock and the lockset race detector (rightly)
        # flags bare polling reads from the test thread.
        def peek(attr):
            with http._lock:
                return getattr(http, attr)

        t = threading.Thread(target=inflight)
        t.start()
        deadline = time.monotonic() + 10
        while peek("_inflight") == 0 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert peek("_inflight") == 1           # request is executing
        stopper = threading.Thread(target=http.stop)
        stopper.start()
        deadline = time.monotonic() + 10
        while not peek("draining") and time.monotonic() < deadline:
            time.sleep(0.01)
        # a request arriving during the drain: clean 503, not a reset
        status, body = raw_post(addr, "/v1/call", {
            "model_spec": {"name": "slow"}, "method": "work",
            "request": {"delay": 0}})
        assert (status, body["error"]["code"]) == (503, "UNAVAILABLE")
        drain_probe = ServingClient(*addr)
        try:
            with pytest.raises(api.Unavailable):
                drain_probe.call(api.ModelSpec("slow"), "work",
                                 {"delay": 0})
        finally:
            drain_probe.close()
        t.join(timeout=30)
        stopper.join(timeout=30)
        assert not errors, errors               # in-flight ran to completion
        assert results == [{"served": True}]
        # post-shutdown: the listener is gone entirely
        dead_probe = ServingClient(*addr)
        try:
            with pytest.raises(api.Unavailable):
                dead_probe.call(api.ModelSpec("slow"), "work", {})
        finally:
            dead_probe.close()


class TestNonFiniteFloats:
    """Bare NaN/Infinity literals are not JSON: scalar non-finite floats
    travel tagged in BOTH codec paths and strict serialization
    (allow_nan=False) guards the transport."""

    @pytest.mark.parametrize("x", [float("nan"), float("inf"),
                                   float("-inf")])
    def test_tagged_value_round_trip_is_strict_json(self, x):
        enc = wire.encode_value({"x": x, "nested": (1.5, [x])})
        s = json.dumps(enc, allow_nan=False)     # strict: must not raise
        dec = wire.decode_value(json.loads(s))
        got = dec["x"]
        assert (got != got) if x != x else got == x
        inner = dec["nested"][1][0]
        assert (inner != inner) if x != x else inner == x

    @pytest.mark.parametrize("x", [float("nan"), float("inf"),
                                   float("-inf"), 2.5])
    def test_typed_message_round_trip_is_strict_json(self, x):
        req = api.GenerateRequest(
            model_spec=api.ModelSpec("clf"),
            tokens=np.asarray([1, 2], np.int32),
            sampling=SamplingParams(temperature=x, seed=3))
        s = json.dumps(wire.encode_message(req), allow_nan=False)
        back = wire.decode_message(api.GenerateRequest, json.loads(s))
        t = back.sampling.temperature
        assert (t != t) if x != x else t == x

    def test_ndarray_nan_payload_stays_exact(self):
        a = np.asarray([np.nan, np.inf, -np.inf, 0.5], np.float32)
        s = json.dumps(wire.encode_ndarray(a), allow_nan=False)
        np.testing.assert_array_equal(
            wire.decode_ndarray(json.loads(s)), a)

    def test_nonfinite_survives_the_wire(self, stack):
        """A non-finite scalar through the generic /v1/call route over a
        real socket: the body is strict JSON end to end."""
        _, http, client = stack
        with pytest.raises(Exception):
            # 'nan_probe' is not a real method — but the request must
            # FAIL TYPED (server decoded the strict-JSON body fine),
            # not die parsing.
            client.call(api.ModelSpec("clf"), "nan_probe",
                        {"x": float("nan"), "y": float("inf")})

    def test_malformed_float_tag_rejected(self):
        with pytest.raises(wire.WireError):
            wire.decode_value({"__wire__": "float", "value": "huge"})


class TestClientCloseAllThreads:
    def test_close_reaps_every_pool_threads_connection(self, stack):
        """A client driven from a (short-lived) thread pool opens one
        keep-alive per worker thread; close() from the main thread must
        close ALL of them — and threads that outlive the close() must
        not silently resurrect their cached (now untracked) conns."""
        from concurrent.futures import ThreadPoolExecutor

        _, http, _ = stack
        client = ServingClient(*http.address)

        def probe(_):
            return client.health()["status"]

        with ThreadPoolExecutor(max_workers=4) as pool:
            assert list(pool.map(probe, range(8))) == ["ok"] * 8
            # the pool threads are still alive here, conns cached
            with client._conns_lock:
                n_before = len(client._conns)
            assert n_before >= 1                 # per-thread keep-alives
            client.close()
            with client._conns_lock:
                assert len(client._conns) == 0   # every one reaped
            # surviving threads re-probe: their stale thread-local conns
            # must be REPLACED (tracked again), not reused untracked
            assert list(pool.map(probe, range(4))) == ["ok"] * 4
            with client._conns_lock:
                live = set(client._conns)
            assert live                          # fresh conns tracked
            client.close()
            with client._conns_lock:
                assert len(client._conns) == 0
            for conn in live:
                assert conn.sock is None         # actually closed

    def test_main_thread_reuse_after_close(self, stack):
        _, http, _ = stack
        client = ServingClient(*http.address)
        assert client.health()["status"] == "ok"
        client.close()
        assert client.health()["status"] == "ok"   # fresh tracked conn
        with client._conns_lock:
            assert len(client._conns) == 1
        client.close()
