"""CLI launcher tests: train.py emits servable versions; serve.py loads
and serves them with canary; dryrun.py single combo (subprocesses — the
real entry points)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def run_cli(args, timeout=400):
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run([sys.executable, "-m", *args], env=env,
                          capture_output=True, text=True,
                          timeout=timeout, cwd=ROOT)


@pytest.fixture(scope="module")
def trained_dir(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("models"))
    r = run_cli(["repro.launch.train", "--arch", "tfs-classifier",
                 "--smoke", "--steps", "30", "--batch-size", "4",
                 "--seq-len", "32", "--out", out, "--emit-every", "15"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "emitted servable version 2" in r.stdout
    return out


def test_train_cli_emits_versions(trained_dir):
    versions = sorted(os.listdir(
        os.path.join(trained_dir, "tfs-classifier")))
    assert versions == ["1", "2"]
    manifest = json.load(open(os.path.join(
        trained_dir, "tfs-classifier", "2", "manifest.json")))
    assert manifest["arch"].startswith("tfs-classifier")
    assert manifest["step"] == 30


def test_serve_cli_serves_with_canary(trained_dir):
    r = run_cli(["repro.launch.serve", "--model-dir", trained_dir,
                 "--name", "tfs-classifier", "--arch", "tfs-classifier",
                 "--smoke", "--requests", "24", "--threads", "2",
                 "--canary"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "traffic:" in r.stdout and "errors=0" in r.stdout
    assert "canary live:" in r.stdout and "(1, 2)" in r.stdout
    assert "promoted:" in r.stdout and "(2,)" in r.stdout


def test_dryrun_cli_single_combo(tmp_path):
    out = str(tmp_path / "rec.jsonl")
    r = run_cli(["repro.launch.dryrun", "--arch", "xlstm-125m",
                 "--shape", "decode_32k", "--mesh", "single",
                 "--out", out], timeout=500)
    assert r.returncode == 0, r.stderr[-2000:]
    rec = json.loads(open(out).read().strip().splitlines()[-1])
    assert rec["status"] == "ok"
    assert rec["fits_hbm_analytic"]
    assert rec["collective_ops"] >= 0
