"""Concurrency properties of the wait-free read path (paper §2.1.2).

The claims under test:
  * readers NEVER observe a partially updated snapshot (RCU publish is
    atomic) and never block on writers;
  * handle refcounting is exact under contention: a servable is freed
    exactly once, only after its last handle is released, and inference
    through a live handle never touches freed memory;
  * inference continues uninterrupted through version churn.
"""
import threading

from _hypothesis_compat import given, settings, st  # optional dep

from repro.core import (AspiredVersion, AspiredVersionsManager,
                        CallableLoader, NotFoundError, RawDictServable,
                        RcuMap, ResourceEstimate, ServableId)


class TestRcuMap:
    def test_snapshot_immutability(self):
        m = RcuMap()
        m.insert("a", 1)
        snap = m.snapshot()
        m.insert("b", 2)
        assert "b" not in snap and "b" in m.snapshot()

    def test_hammered_readers_see_consistent_pairs(self):
        """Writers keep publishing {x: n, y: n}; readers must never see
        x and y from different publishes in one snapshot."""
        m = RcuMap()
        m.update_many({"x": 0, "y": 0})
        stop = threading.Event()
        bad = []

        def reader():
            while not stop.is_set():
                snap = m.snapshot()
                if snap["x"] != snap["y"]:
                    bad.append((snap["x"], snap["y"]))

        def writer():
            for n in range(1, 2000):
                m.update_many({"x": n, "y": n})

        readers = [threading.Thread(target=reader) for _ in range(4)]
        [t.start() for t in readers]
        writer()
        stop.set()
        [t.join() for t in readers]
        assert not bad, bad[:5]

    @given(st.lists(st.tuples(st.sampled_from("abcd"),
                              st.integers(0, 5),
                              st.booleans()), max_size=60))
    @settings(max_examples=100, deadline=None)
    def test_matches_dict_semantics(self, ops):
        m = RcuMap()
        ref = {}
        for key, val, is_insert in ops:
            if is_insert:
                m.insert(key, val)
                ref[key] = val
            else:
                assert m.remove(key) == ref.pop(key, None)
            assert dict(m.snapshot()) == ref
            assert len(m) == len(ref)


class FreeTracker(RawDictServable):
    freed = None  # set per-test

    def unload(self):
        type(self).freed.append((self.id, threading.current_thread().name))
        super().unload()


class TestHandleRefcounting:
    def test_free_happens_once_on_manager_thread(self):
        FreeTracker.freed = []
        mgr = AspiredVersionsManager()
        sid = ServableId("m", 1)
        mgr.set_aspired_versions("m", [AspiredVersion(
            sid, CallableLoader(sid,
                                lambda: FreeTracker(sid, {"v": 1}),
                                ResourceEstimate(ram_bytes=10)))])
        assert mgr.await_idle()
        handles = [mgr.get_servable_handle("m") for _ in range(8)]
        mgr.set_aspired_versions("m", [])
        mgr.reconcile()
        # release from many threads at once
        ts = [threading.Thread(target=h.release) for h in handles]
        [t.start() for t in ts]
        [t.join() for t in ts]
        assert mgr.await_idle()
        assert len(FreeTracker.freed) == 1
        sid_freed, thread_name = FreeTracker.freed[0]
        assert sid_freed == sid
        # the paper's guarantee: the free ran on the manager's unload
        # executor, NOT on any releasing (inference) thread
        assert thread_name.startswith("tfs-manager-unload")
        mgr.shutdown()

    def test_inference_through_version_churn(self):
        """Clients keep issuing lookups while versions churn 1..N; every
        lookup must succeed and return a value consistent with SOME
        then-live version."""
        mgr = AspiredVersionsManager(num_load_threads=2)
        def aspire(v):
            sid = ServableId("m", v)
            mgr.set_aspired_versions("m", [AspiredVersion(
                sid, CallableLoader(
                    sid, lambda sid=sid: RawDictServable(
                        sid, {"v": sid.version}),
                    ResourceEstimate(ram_bytes=10)))])
        aspire(1)
        assert mgr.await_idle()
        stop = threading.Event()
        errors = []

        def client():
            while not stop.is_set():
                try:
                    with mgr.get_servable_handle("m") as s:
                        val = s.call("lookup", "v")
                        if not isinstance(val, int):
                            errors.append(("badval", val))
                except NotFoundError:
                    errors.append(("notfound",))
                except Exception as e:  # pragma: no cover
                    errors.append(("exc", repr(e)))

        clients = [threading.Thread(target=client) for _ in range(4)]
        [t.start() for t in clients]
        for v in range(2, 12):
            aspire(v)
            assert mgr.await_idle(timeout_s=20)
        stop.set()
        [t.join() for t in clients]
        assert not errors, errors[:5]
        assert mgr.list_available() == {"m": (11,)}
        mgr.shutdown()
