"""Regression tests for the true races surfaced by the shared-state
analysis + lockset detector pass. Each test fails against the pre-fix
code (double-spawned background threads, a timer re-armed after
stop_polling, a lock-free drain flag, and a lost conflict counter) and
pins the fixed behaviour.
"""
import itertools
import sys
import threading
import time

import jax

from repro.configs import get_config
from repro.core.manager import AspiredVersionsManager
from repro.core.source import FileSystemSource
from repro.hosted.store import TransactionalStore
from repro.models import model as MD
from repro.serving.decode_engine import DecodeScheduler
from repro.serving.transport import HttpServingServer

CFG = get_config("tfs-classifier", smoke=True).with_overrides(
    dtype="float32")


def _alive_named(name):
    return [t for t in threading.enumerate()
            if t.name == name and t.is_alive()]


class TestDoubleStart:
    """start() used to spawn a second background thread on every call;
    two loops mutating the same scheduler state is a race all by
    itself (and the first thread leaked, unjoinable, on stop)."""

    def test_decode_scheduler_start_is_idempotent(self):
        params = MD.init_params(jax.random.PRNGKey(0), CFG)
        eng = DecodeScheduler(CFG, params, num_slots=1, max_seq_len=32)
        before = len(_alive_named("decode-engine"))
        try:
            eng.start()
            eng.start()
            assert len(_alive_named("decode-engine")) == before + 1
        finally:
            eng.stop()
        assert len(_alive_named("decode-engine")) == before

    def test_manager_start_is_idempotent(self):
        mgr = AspiredVersionsManager()
        before = len(_alive_named("tfs-manage-loop"))
        try:
            mgr.start(interval_s=30.0)
            mgr.start(interval_s=30.0)
            assert len(_alive_named("tfs-manage-loop")) == before + 1
        finally:
            mgr.stop()
        assert len(_alive_named("tfs-manage-loop")) == before


class TestPollingStopRace:
    def test_stop_during_tick_never_rearms(self, tmp_path):
        """stop_polling concurrent with a tick: the pre-fix tick()
        re-armed the next Timer unconditionally after poll(), so a stop
        that landed while poll() was in flight could only cancel the
        *previous* timer and polling resurrected itself. The fixed tick
        re-checks ``_stopped`` under ``_poll_lock`` before re-arming."""
        src = FileSystemSource({"m": str(tmp_path)})

        orig_poll = src.poll

        def poll_then_stop():
            orig_poll()
            src.stop_polling()      # races the re-arm in the same tick

        src.poll = poll_then_stop
        try:
            # The first tick runs inline, so the race resolves before
            # start_polling returns.
            src.start_polling(3600.0)
            with src._poll_lock:
                timer, stopped = src._timer, src._stopped
            assert stopped
            assert timer is None, "timer re-armed after stop_polling"
        finally:
            src.poll = orig_poll
            src.stop_polling()


class TestTransportStopRaces:
    def test_concurrent_stop_and_inflight_request(self):
        """A drain-mode stop() racing a second stop(): pre-fix, stop()
        tore down ``_httpd`` outside ``_lock`` after the drain wait, so
        the loser shut down an already-closed server."""
        srv = HttpServingServer(None, port=0, drain_timeout_s=10.0)
        srv.start()
        errors = []
        try:
            assert srv.enter_request()

            def drain_stop():
                try:
                    srv.stop()      # blocks on the in-flight request
                except Exception as exc:  # noqa: BLE001 — asserted below
                    errors.append(exc)

            t = threading.Thread(target=drain_stop)
            t.start()
            deadline = 5.0
            while not srv.is_draining() and deadline > 0:
                threading.Event().wait(0.005)
                deadline -= 0.005
            assert srv.is_draining()
            srv.stop(drain=False)       # concurrent second stop
            srv.exit_request()          # lets the drain wait wake up
            t.join(10)
            assert not t.is_alive()
            assert errors == []
        finally:
            srv.stop(drain=False)

    def test_is_draining_reads_under_lock(self):
        """/healthz used to read ``draining`` lock-free from handler
        threads; is_draining() is the locked accessor it now uses."""
        srv = HttpServingServer(None, port=0)
        assert srv.is_draining() is False
        srv.start()
        try:
            assert srv.is_draining() is False
        finally:
            srv.stop(drain=False)
        assert srv.is_draining() is True


class TestStoreConflictCounter:
    def test_conflicts_exactly_account_failed_commits(self):
        """``conflicts += 1`` used to run outside ``_lock`` in the
        transact retry loop — concurrent increments were lost, so
        conflicts drifted below attempts - commits. The counter now
        bumps inside _commit's validation-failure branch, under the
        same lock as the validation itself."""
        store = TransactionalStore()
        store.transact(lambda txn: txn.put("k", 0))
        attempts = itertools.count()
        orig_commit = store._commit

        def counted_commit(txn):
            next(attempts)
            return orig_commit(txn)

        store._commit = counted_commit
        n_threads, rounds = 8, 25
        barrier = threading.Barrier(n_threads)
        old_interval = sys.getswitchinterval()
        sys.setswitchinterval(1e-6)     # force interleaving mid-transact

        def bump(txn):
            v = txn.get("k")
            time.sleep(0.001)   # widen the read->commit conflict window
            txn.put("k", v + 1)

        def contend():
            barrier.wait(10)
            for _ in range(rounds):
                store.transact(bump, max_retries=10_000)

        threads = [threading.Thread(target=contend)
                   for _ in range(n_threads)]
        try:
            for t in threads:
                t.start()
            for t in threads:
                t.join(60)
        finally:
            sys.setswitchinterval(old_interval)
            store._commit = orig_commit

        assert all(not t.is_alive() for t in threads)
        commits = n_threads * rounds
        assert store.get("k") == commits
        total_attempts = next(attempts)
        # exact bookkeeping: every failed commit is one counted conflict
        assert store.commits == commits + 1     # +1 seeds "k"
        assert store.conflicts == total_attempts - commits
        assert store.conflicts > 0, (
            "no contention generated — test needs more interleaving")
