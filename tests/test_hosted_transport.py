"""TFS² over real sockets: Router -> JobReplica traffic crossing
localhost through ServingClient, Synchronizer-propagated
SetVersionLabels, and the scenario sweep — canary by label under
concurrent load, promote via propagated labels, live reconfiguration
with in-flight traffic, zero dropped requests."""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (CallableLoader, RawDictServable, ResourceEstimate,
                        ServableId)
from repro.hosted import (Controller, ModelSpec, Router, ServingJob,
                          Synchronizer, TransactionalStore)
from repro.models import model as MD
from repro.serving import api
from repro.serving.engine import JaxModelServable


def dict_loader_factory(name, version, ref, ram):
    sid = ServableId(name, version)
    return CallableLoader(
        sid, lambda: RawDictServable(sid, {"v": version}, ram_bytes=ram),
        ResourceEstimate(ram_bytes=ram))


CFG = get_config("tfs-classifier", smoke=True)


def jax_loader_factory(name, version, ref, ram):
    sid = ServableId(name, version)

    def build():
        params = MD.init_params(jax.random.PRNGKey(version), CFG)
        return JaxModelServable(sid, CFG, params)
    return CallableLoader(sid, build, ResourceEstimate(ram_bytes=ram))


@pytest.fixture()
def stack(request):
    """Hosted stack with every replica serving on its own port."""
    factory = getattr(request, "param", dict_loader_factory)
    jobs = {"j1": ServingJob("j1", 10_000, min_replicas=2,
                             serve_replicas=True)}
    store = TransactionalStore()
    ctrl = Controller(store, {"j1": 10_000})
    sync = Synchronizer("dc", ctrl, jobs, factory)
    router = Router(sync, jobs, hedge_delay_s=None)
    yield jobs, ctrl, sync, router
    router.shutdown()
    sync.shutdown()
    for j in jobs.values():
        j.shutdown()


class TestRouterOverSockets:
    def test_traffic_crosses_real_sockets(self, stack):
        jobs, ctrl, sync, router = stack
        ctrl.add_model("m", 100)
        assert sync.sync_once() == {"j1": {"m": (1,)}}
        for r in jobs["j1"].replicas:
            assert r.address is not None
        before = [r.transport.requests_served
                  for r in jobs["j1"].replicas]
        for _ in range(4):
            assert router.infer("m", "v", method="lookup") == 1
        after = [r.transport.requests_served for r in jobs["j1"].replicas]
        assert sum(after) - sum(before) == 4    # every request on the wire
        # ... via the replica-owned shared ServingClients
        assert any(r._client is not None for r in jobs["j1"].replicas)

    def test_inproc_transport_opt_out(self, stack):
        jobs, ctrl, sync, router = stack
        ctrl.add_model("m", 100)
        sync.sync_once()
        inproc = Router(sync, jobs, hedge_delay_s=None,
                        transport="inproc")
        try:
            before = [r.transport.requests_served
                      for r in jobs["j1"].replicas]
            assert inproc.infer("m", "v", method="lookup") == 1
            after = [r.transport.requests_served
                     for r in jobs["j1"].replicas]
            assert after == before          # nothing touched the wire
        finally:
            inproc.shutdown()

    @pytest.mark.parametrize("stack", [jax_loader_factory],
                             indirect=True)
    def test_tensor_payloads_over_sockets(self, stack):
        """Real model, real tensors, real wire: routed predict output is
        bit-identical to the replica's in-process result."""
        jobs, ctrl, sync, router = stack
        ctrl.add_model("m", 100)
        sync.sync_once()
        b = {"tokens": np.random.default_rng(0).integers(
            0, CFG.vocab_size, (2, 16))}
        out = router.infer("m", b, method="predict")
        ref = jobs["j1"].replicas[0].prediction.call(
            ModelSpec("m"), "predict", b)
        assert isinstance(out, np.ndarray)
        assert out.dtype == ref.dtype and out.tobytes() == ref.tobytes()

    def test_label_propagation_cluster_wide(self, stack):
        jobs, ctrl, sync, router = stack
        ctrl.add_model("m", 100)
        sync.sync_once()
        ctrl.add_version("m", 2)
        ctrl.set_policy("m", "canary")
        sync.sync_once()
        applied = sync.set_version_labels("m", {"prod": 1})
        assert applied == len(jobs["j1"].replicas)
        for r in jobs["j1"].replicas:       # every replica, over the wire
            assert r.manager.version_labels("m")["prod"] == 1
        assert router.infer(ModelSpec("m", label="prod"), "v",
                            method="lookup") == 1
        # promote: one operator call, propagated everywhere
        sync.set_version_labels("m", {"prod": 2})
        for _ in range(2 * len(jobs["j1"].replicas)):
            assert router.infer(ModelSpec("m", label="prod"), "v",
                                method="lookup") == 2
        # new replicas converge on the next sync
        jobs["j1"].scale_to(3)
        sync.sync_once()
        assert jobs["j1"].replicas[2].manager.version_labels(
            "m")["prod"] == 2

    def test_label_clear_converges_after_missed_push(self, stack):
        """A clear is a tombstone: a replica that missed it (transient
        push failure) converges at the next sync instead of serving a
        stale pin forever."""
        jobs, ctrl, sync, router = stack
        ctrl.add_model("m", 100)
        sync.sync_once()
        sync.set_version_labels("m", {"prod": 1})
        sync.set_version_labels("m", {"prod": None})
        # simulate a replica the clear never reached
        jobs["j1"].replicas[0].models.set_version_labels("m", {"prod": 1})
        assert "prod" in jobs["j1"].replicas[0].manager.version_labels(
            "m")
        sync.sync_once()                    # tombstone re-pushed
        for r in jobs["j1"].replicas:
            assert "prod" not in r.manager.version_labels("m")
        assert sync.version_labels("m") == {}

    def test_label_on_unloaded_version_typed_error(self, stack):
        jobs, ctrl, sync, router = stack
        ctrl.add_model("m", 100)
        sync.sync_once()
        with pytest.raises(api.FailedPrecondition):
            sync.set_version_labels("m", {"prod": 99})
        with pytest.raises(api.NotFound):
            sync.set_version_labels("ghost", {"prod": 1})


class TestScenarioSweep:
    def test_canary_promote_reload_under_load_zero_drops(self, stack):
        """The TFS² scenario sweep seed (ROADMAP), across real sockets:
        label-addressed traffic runs CONCURRENTLY with (1) a canary
        rollout, (2) a promote via Synchronizer-propagated
        SetVersionLabels, and (3) a live version reconfiguration — and
        no request is ever dropped or mis-routed to a non-READY
        version."""
        jobs, ctrl, sync, router = stack
        ctrl.add_model("m", 100)
        sync.sync_once()
        sync.set_version_labels("m", {"prod": 1})

        stop = threading.Event()
        errors, served = [], [0]
        lock = threading.Lock()
        prod_seen = set()

        def client(i):
            while not stop.is_set():
                try:
                    v_prod = router.infer(ModelSpec("m", label="prod"),
                                          "v", method="lookup")
                    v_any = router.infer("m", "v", method="lookup")
                    with lock:
                        prod_seen.add(v_prod)
                        served[0] += 1
                    assert v_prod in (1, 2) and v_any in (1, 2, 3)
                except Exception as exc:    # any failure is a drop
                    with lock:
                        errors.append(exc)
                    return

        ts = [threading.Thread(target=client, args=(i,))
              for i in range(4)]
        [t.start() for t in ts]
        try:
            # (1) canary rollout under load
            ctrl.add_version("m", 2)
            ctrl.set_policy("m", "canary")
            sync.sync_once()
            assert router.infer(ModelSpec("m", label="canary"), "v",
                                method="lookup") == 2
            # (2) promote prod 1 -> 2 via the Synchronizer (every
            # replica flips atomically; label-addressed traffic never
            # strands)
            sync.set_version_labels("m", {"prod": 2})
            # (3) live reconfiguration with in-flight traffic: v3
            # arrives, policy back to latest, v1/v2 retire (prod=2 was
            # re-asserted, then follows v2 out when it retires)
            ctrl.add_version("m", 3)
            sync.sync_once()
            # More label-addressed load: run until the clients have
            # demonstrably served concurrent traffic (a fixed sleep
            # makes the threshold below a machine-speed lottery).
            deadline = time.monotonic() + 30
            while (served[0] < 30 and not errors
                   and any(t.is_alive() for t in ts)
                   and time.monotonic() < deadline):
                time.sleep(0.05)
        finally:
            stop.set()
            [t.join(timeout=60) for t in ts]
        assert not errors, errors
        assert served[0] >= 20      # real concurrency, real sockets
        assert prod_seen <= {1, 2}
        # final state: latest-only again after the canary experiment
        ctrl.set_policy("m", "latest")
        assert sync.sync_once() == {"j1": {"m": (3,)}}
        assert router.infer("m", "v", method="lookup") == 3
