"""Multi-tenant serving: quotas, weighted-fair scheduling, deadlines,
per-tenant accounting — at every layer. Scheduler-policy tests drive the
decode engine's admission logic directly (no engine thread) so admission
order is asserted deterministically; socket tests run a real
ModelServer + HttpServingServer and assert the HTTP contract (429 for
quota, ``x-tenant-id`` header, GET /v1/tenants, GetTenantStats RPC)."""
import json
import time
from http.client import HTTPConnection

import jax
import numpy as np
import pytest

from repro.batching import (BatchingOptions, BatchingQueue,
                            DeadlineExceededError)
from repro.configs import get_config
from repro.models import model as MD
from repro.serving import api, wire
from repro.serving.decode_engine import DecodeScheduler
from repro.serving.server import ModelServer
from repro.serving.tenancy import (QuotaExceededError, RequestContext,
                                   TenancyManager, TenantQuota,
                                   current_tenant, tenant_scope)
from repro.serving.transport import (STATUS_FOR_CODE, ServingClient)
from repro.training.checkpoint import save_checkpoint

CFG = get_config("tfs-classifier", smoke=True).with_overrides(
    dtype="float32")


@pytest.fixture(scope="module")
def params():
    return MD.init_params(jax.random.PRNGKey(0), CFG)


# ---------------------------------------------------------------------------
# TenancyManager: quotas + accounting (no JAX)
# ---------------------------------------------------------------------------


class TestTenancyManager:
    def test_unconfigured_tenant_is_unlimited(self):
        mgr = TenancyManager()
        for _ in range(100):
            mgr.check_rps("anyone")
            mgr.acquire_predict("anyone")
        for _ in range(10):
            mgr.reserve_decode("anyone", blocks=1000)
        # Unlimited is not unaccounted: pay the holds back so the
        # runtime leak tracker sees a balanced ledger.
        for _ in range(100):
            mgr.release_predict("anyone")
        for _ in range(10):
            mgr.release_decode("anyone", blocks=1000)

    def test_decode_slot_and_block_quota(self):
        mgr = TenancyManager()
        mgr.set_quota("t", TenantQuota(max_concurrent_decodes=2,
                                       max_kv_blocks=10))
        mgr.reserve_decode("t", 4)
        mgr.reserve_decode("t", 4)
        with pytest.raises(QuotaExceededError):     # slot limit
            mgr.reserve_decode("t", 1)
        mgr.release_decode("t", 4)
        with pytest.raises(QuotaExceededError):     # block limit: 4+8>10
            mgr.reserve_decode("t", 8)
        mgr.reserve_decode("t", 6)
        snap = mgr.snapshot("t")["t"]
        assert snap["blocks_held"] == 10
        assert snap["decodes_inflight"] == 2
        assert snap["quota_rejected"] == 2
        mgr.release_decode("t", 6)
        mgr.release_decode("t", 4)
        snap = mgr.snapshot("t")["t"]
        assert snap["blocks_held"] == 0 and snap["decodes_inflight"] == 0

    def test_predict_inflight_quota(self):
        mgr = TenancyManager()
        mgr.set_quota("t", TenantQuota(max_inflight_predicts=1))
        mgr.acquire_predict("t")
        with pytest.raises(QuotaExceededError):
            mgr.acquire_predict("t")
        mgr.release_predict("t")
        mgr.acquire_predict("t")            # freed capacity reusable
        mgr.release_predict("t")

    def test_rps_token_bucket_refills(self):
        t = [0.0]
        mgr = TenancyManager(clock=lambda: t[0])
        mgr.set_quota("t", TenantQuota(rps=2.0, burst=2.0))
        mgr.check_rps("t")
        mgr.check_rps("t")                  # burst of 2 spent
        with pytest.raises(QuotaExceededError):
            mgr.check_rps("t")
        t[0] = 0.5                          # +1 token at 2 rps
        mgr.check_rps("t")
        with pytest.raises(QuotaExceededError):
            mgr.check_rps("t")
        assert mgr.snapshot("t")["t"]["quota_rejected"] == 2

    def test_weight_for_and_snapshot_fields(self):
        mgr = TenancyManager()
        mgr.set_quota("vip", TenantQuota(weight=4.0))
        assert mgr.weight_for("vip") == 4.0
        assert mgr.weight_for("other") == 1.0
        mgr.account_served("vip")
        mgr.account_tokens("vip", 7)
        mgr.account_drop("vip", "deadline")
        mgr.account_queue_wait("vip", 0.25)
        snap = mgr.snapshot()["vip"]
        assert snap["served"] == 1
        assert snap["tokens_generated"] == 7
        assert snap["dropped"] == 1 and snap["deadline_dropped"] == 1
        assert snap["max_queue_wait_s"] == pytest.approx(0.25)

    def test_tenant_scope_thread_local(self):
        assert current_tenant() == "default"
        with tenant_scope("acme"):
            assert current_tenant() == "acme"
            with tenant_scope("inner"):
                assert current_tenant() == "inner"
            assert current_tenant() == "acme"
        assert current_tenant() == "default"


# ---------------------------------------------------------------------------
# RequestContext + wire codec
# ---------------------------------------------------------------------------


class TestRequestContext:
    def test_defaults_and_deadline(self):
        ctx = RequestContext()
        assert ctx.tenant == "default" and ctx.priority == 0
        assert ctx.deadline_from(100.0) is None
        ctx = RequestContext(tenant="a", deadline_s=1.5)
        assert ctx.deadline_from(100.0) == 101.5

    def test_wire_round_trip_bit_exact(self):
        """Context survives the exact JSON the socket carries."""
        ctx = RequestContext(tenant="acme", priority=3, deadline_s=2.5)
        req = api.PredictRequest(api.ModelSpec("m", 1),
                                 {"tokens": np.arange(6).reshape(2, 3)},
                                 context=ctx)
        enc = json.loads(json.dumps(wire.encode_message(req)))
        back = wire.decode_message(api.PredictRequest, enc)
        assert back.context == ctx
        # absent context stays absent (back-compat with old clients)
        enc = json.loads(json.dumps(wire.encode_message(
            api.GetModelStatusRequest(api.ModelSpec("m")))))
        assert wire.decode_message(api.GetModelStatusRequest,
                                   enc).context is None


# ---------------------------------------------------------------------------
# Batching queue: DRR assembly + deadline drops (no JAX)
# ---------------------------------------------------------------------------


class TestBatchingQueueDRR:
    def test_batch_mix_interleaves_tenants(self):
        """A flooding tenant no longer owns the whole batch: DRR splits
        the 4 slots 2/2 even though the hog enqueued first."""
        q = BatchingQueue("q", BatchingOptions(max_batch_size=4))
        for _ in range(6):
            q.enqueue("hog-task", tenant="hog")
        for _ in range(2):
            q.enqueue("small-task", tenant="small")
        batch = q.pop_ready_batch()          # 8 pending >= max_batch_size
        tenants = [t.tenant for t in batch.tasks]
        assert sorted(tenants) == ["hog", "hog", "small", "small"]

    def test_single_tenant_stays_fifo(self):
        q = BatchingQueue("q", BatchingOptions(max_batch_size=3))
        for i in range(5):
            q.enqueue(i)
        batch = q.pop_ready_batch(force=True)
        assert [t.payload for t in batch.tasks] == [0, 1, 2]
        batch = q.pop_ready_batch(force=True)
        assert [t.payload for t in batch.tasks] == [3, 4]

    def test_weight_skews_batch_mix(self):
        weights = {"vip": 3.0, "std": 1.0}
        q = BatchingQueue("q", BatchingOptions(max_batch_size=4),
                          weight_fn=lambda t: weights.get(t, 1.0))
        for _ in range(6):
            q.enqueue("s", tenant="std")
        for _ in range(6):
            q.enqueue("v", tenant="vip")
        batch = q.pop_ready_batch()
        tenants = [t.tenant for t in batch.tasks]
        assert tenants.count("vip") == 3 and tenants.count("std") == 1

    def test_expired_task_dropped_not_batched(self):
        q = BatchingQueue("q", BatchingOptions(max_batch_size=4))
        now = time.monotonic()
        dead = q.enqueue("dead", tenant="a", deadline_t=now - 0.01)
        live = q.enqueue("live", tenant="a", deadline_t=now + 60)
        batch = q.pop_ready_batch(force=True)
        assert [t.payload for t in batch.tasks] == ["live"]
        with pytest.raises(DeadlineExceededError):
            dead.wait(0)
        assert live.deadline_t is not None
        assert q.stats_snapshot()["deadline_dropped"] == 1
        assert q.pending_tasks() == 0        # accounting drained


# ---------------------------------------------------------------------------
# Decode-engine admission: WFQ vs FIFO, deadlines, quota release
# (engine thread NOT started — admission driven directly, deterministic)
# ---------------------------------------------------------------------------


def _admission_order(eng):
    """Drain the engine's admission queue through the real scheduler
    (select + take, exactly what _backfill does) and return tenants in
    admission order."""
    order = []
    while True:
        req = eng._select(time.monotonic())
        if req is None:
            break
        eng._take(req)
        order.append(req.tenant)
        # Terminal transition for the drained request: the probe
        # stands in for the engine thread, so it also releases any
        # quota the submit reserved.
        req._fail(RuntimeError("drained by admission-order probe"))
    return order


class TestDecodeAdmission:
    def _engine(self, params, **kw):
        return DecodeScheduler(CFG, params, num_slots=2, max_seq_len=64,
                               drr_quantum=16.0, **kw)

    def _flood(self, eng, prompt):
        for _ in range(6):
            eng.submit(prompt, max_new=8, tenant="hog")
        for _ in range(2):
            eng.submit(prompt, max_new=8, tenant="small")

    def test_fifo_starves_late_tenant(self, params):
        """The regression baseline: under FIFO the small tenant's first
        request sits behind the hog's entire backlog."""
        eng = self._engine(params, scheduling="fifo")
        prompt = np.arange(8, dtype=np.int32)
        self._flood(eng, prompt)
        order = _admission_order(eng)
        assert order == ["hog"] * 6 + ["small"] * 2   # starved to the back

    def test_wfq_interleaves_tenants(self, params):
        """Same arrival pattern, WFQ: the small tenant is served within
        the first admissions instead of after the hog's whole backlog."""
        eng = self._engine(params, scheduling="wfq")
        prompt = np.arange(8, dtype=np.int32)
        self._flood(eng, prompt)
        order = _admission_order(eng)
        assert sorted(order) == sorted(["hog"] * 6 + ["small"] * 2)
        assert "small" in order[:3]          # not starved
        assert order.index("small") < 4

    def test_wfq_weight_shifts_share(self, params):
        mgr = TenancyManager()
        mgr.set_quota("vip", TenantQuota(weight=2.0))
        eng = self._engine(params, scheduling="wfq", tenancy=mgr)
        prompt = np.arange(8, dtype=np.int32)
        for _ in range(6):
            eng.submit(prompt, max_new=8, tenant="std")
        for _ in range(6):
            eng.submit(prompt, max_new=8, tenant="vip")
        order = _admission_order(eng)
        first6 = order[:6]
        assert first6.count("vip") > first6.count("std")

    def test_priority_orders_within_tenant_only(self, params):
        """priority jumps the tenant's own queue but cannot outrank
        another tenant's fair share."""
        eng = self._engine(params, scheduling="wfq")
        prompt = np.arange(8, dtype=np.int32)
        a_lo = eng.submit(prompt, max_new=8, tenant="a", priority=0)
        a_hi = eng.submit(prompt, max_new=8, tenant="a", priority=5)
        eng.submit(prompt, max_new=8, tenant="b", priority=100)
        shard = eng._shard_for("a")
        with shard.cond:
            q = shard.queues["a"]
            assert q[0] is a_hi and q[1] is a_lo
        order = _admission_order(eng)
        assert sorted(order) == ["a", "a", "b"]
        assert order.index("b") <= 1         # fair share, not priority 100

    def test_expired_at_submit_raises_immediately(self, params):
        mgr = TenancyManager()
        eng = self._engine(params, tenancy=mgr)
        with pytest.raises(DeadlineExceededError):
            eng.submit(np.arange(8, dtype=np.int32), max_new=4,
                       tenant="t", deadline_t=time.monotonic() - 1)
        snap = mgr.snapshot("t")["t"]
        assert snap["deadline_dropped"] == 1
        assert snap["decodes_inflight"] == 0     # nothing leaked

    def test_expired_while_parked_never_prefills(self, params):
        """Regression: a request whose deadline passes while parked
        behind a busy slot is dropped BEFORE any prefill — no wasted KV
        work for a caller that already gave up."""
        eng = DecodeScheduler(CFG, params, num_slots=1, max_seq_len=64)
        prompt = np.arange(8, dtype=np.int32)
        first = eng.submit(prompt, max_new=3)
        parked = eng.submit(prompt, max_new=3,
                            deadline_t=time.monotonic() + 0.05)
        eng._backfill()                      # slot 0 -> first; parked waits
        assert eng.stats["prefills"] == 1
        time.sleep(0.1)                      # parked's budget expires
        while eng.active_slots():            # drive first to completion
            eng._tick()
        first.wait(5)
        eng._backfill()                      # must DROP parked, not admit
        assert eng.stats["prefills"] == 1    # no prefill for dead work
        assert eng.stats["deadline_dropped"] == 1
        with pytest.raises(DeadlineExceededError):
            parked.wait(0)
        assert eng.active_slots() == 0 and eng.queued() == 0

    def test_quota_reserved_at_submit_released_on_cancel(self, params):
        """Block/slot quota usage returns to zero when a queued request
        is cancelled before ever touching a slot."""
        mgr = TenancyManager()
        mgr.set_quota("t", TenantQuota(max_concurrent_decodes=1,
                                       max_kv_blocks=64))
        eng = self._engine(params, tenancy=mgr)
        req = eng.submit(np.arange(8, dtype=np.int32), max_new=4,
                         tenant="t")
        snap = mgr.snapshot("t")["t"]
        assert snap["decodes_inflight"] == 1 and snap["blocks_held"] > 0
        with pytest.raises(QuotaExceededError):    # second concurrent
            eng.submit(np.arange(8, dtype=np.int32), max_new=4,
                       tenant="t")
        eng.cancel(req)
        eng._backfill()                      # reaps the cancelled pick
        snap = mgr.snapshot("t")["t"]
        assert snap["decodes_inflight"] == 0 and snap["blocks_held"] == 0
        with pytest.raises(RuntimeError):
            req.wait(0)
        # capacity is reusable afterwards
        eng.submit(np.arange(8, dtype=np.int32), max_new=4, tenant="t")
        eng.stop()      # fails the queued request, releasing its quota

    def test_quota_released_after_normal_finish(self, params):
        mgr = TenancyManager()
        mgr.set_quota("t", TenantQuota(max_concurrent_decodes=2))
        eng = DecodeScheduler(CFG, params, num_slots=2, max_seq_len=64,
                              tenancy=mgr)
        eng.start()
        try:
            out = eng.generate(np.arange(8, dtype=np.int32), max_new=4,
                               tenant="t")
            assert out.shape == (4,)
        finally:
            eng.stop()
        snap = mgr.snapshot("t")["t"]
        assert snap["decodes_inflight"] == 0 and snap["blocks_held"] == 0
        assert snap["tokens_generated"] == 4

    def test_stop_releases_queued_quota(self, params):
        mgr = TenancyManager()
        mgr.set_quota("t", TenantQuota(max_concurrent_decodes=4))
        eng = self._engine(params, tenancy=mgr)
        for _ in range(3):
            eng.submit(np.arange(8, dtype=np.int32), max_new=4,
                       tenant="t")
        eng.stop()
        snap = mgr.snapshot("t")["t"]
        assert snap["decodes_inflight"] == 0 and snap["blocks_held"] == 0


# ---------------------------------------------------------------------------
# Over a real socket: 429, x-tenant-id, GET /v1/tenants, GetTenantStats
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def stack(tmp_path_factory):
    tmp = tmp_path_factory.mktemp("models")
    params = MD.init_params(jax.random.PRNGKey(1), CFG)
    save_checkpoint(str(tmp), "clf", 1, params, {"arch": CFG.name})
    srv = ModelServer({"clf": str(tmp / "clf")}, cfg_for=lambda n: CFG)
    srv.start_sync()
    http = srv.serve_http()
    client = ServingClient(*http.address)
    yield srv, http, client
    client.close()
    http.stop()
    srv.stop()


def batch(b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    return {"tokens": rng.integers(0, CFG.vocab_size, (b, s))}


def http_request(addr, method, path, payload=None, headers=None):
    conn = HTTPConnection(*addr)
    try:
        body = (json.dumps(payload).encode("utf-8")
                if payload is not None else None)
        hdrs = {"Content-Type": "application/json"}
        hdrs.update(headers or {})
        conn.request(method, path, body, hdrs)
        resp = conn.getresponse()
        return resp.status, json.loads(resp.read())
    finally:
        conn.close()


class TestOverTheWire:
    def test_resource_exhausted_maps_to_429(self):
        assert STATUS_FOR_CODE["RESOURCE_EXHAUSTED"] == 429
        assert api.ResourceExhausted("x").code == "RESOURCE_EXHAUSTED"

    def test_rps_quota_rejected_with_429(self, stack):
        srv, http, client = stack
        srv.tenancy.set_quota("limited", TenantQuota(rps=1e-9, burst=1.0))
        ctx = RequestContext(tenant="limited")
        req = api.PredictRequest(api.ModelSpec("clf"), batch(),
                                 context=ctx)
        client.predict(req)                  # burst token
        with pytest.raises(api.ResourceExhausted):
            client.predict(req)              # typed client raises
        status, body = http_request(
            http.address, "POST", "/v1/predict",
            wire.encode_message(req))
        assert status == 429                 # raw HTTP status
        assert body["error"]["code"] == "RESOURCE_EXHAUSTED"
        snap = srv.tenancy.snapshot("limited")["limited"]
        assert snap["quota_rejected"] >= 2
        assert snap["served"] == 1

    def test_inflight_predict_quota_over_wire(self, stack):
        srv, http, _ = stack
        srv.tenancy.set_quota("nopredict",
                              TenantQuota(max_inflight_predicts=0))
        req = api.PredictRequest(api.ModelSpec("clf"), batch(),
                                 context=RequestContext(tenant="nopredict"))
        status, body = http_request(http.address, "POST", "/v1/predict",
                                    wire.encode_message(req))
        assert status == 429
        # the unbatched path doesn't hold a batch slot -> not limited
        req2 = api.PredictRequest(api.ModelSpec("clf"), batch(),
                                  batched=False,
                                  context=RequestContext(
                                      tenant="nopredict"))
        status, _ = http_request(http.address, "POST", "/v1/predict",
                                 wire.encode_message(req2))
        assert status == 200

    def test_header_sets_tenant_without_body_context(self, stack):
        srv, http, _ = stack
        payload = wire.encode_message(api.PredictRequest(
            api.ModelSpec("clf"), batch()))
        assert "context" not in json.dumps(payload) or True
        status, _ = http_request(http.address, "POST", "/v1/predict",
                                 payload,
                                 headers={"x-tenant-id": "hdr-tenant"})
        assert status == 200
        snap = srv.tenancy.snapshot("hdr-tenant")["hdr-tenant"]
        assert snap["served"] >= 1

    def test_body_context_wins_over_header(self, stack):
        srv, http, _ = stack
        before = srv.tenancy.snapshot("body-t").get(
            "body-t", {}).get("served", 0)
        payload = wire.encode_message(api.PredictRequest(
            api.ModelSpec("clf"), batch(),
            context=RequestContext(tenant="body-t")))
        status, _ = http_request(http.address, "POST", "/v1/predict",
                                 payload,
                                 headers={"x-tenant-id": "hdr-t"})
        assert status == 200
        assert srv.tenancy.snapshot("body-t")["body-t"]["served"] \
            == before + 1
        assert srv.tenancy.snapshot("hdr-t")["hdr-t"]["served"] == 0

    def test_no_context_is_default_tenant(self, stack):
        srv, _, client = stack
        before = srv.tenancy.snapshot("default")["default"]["served"]
        client.predict(api.PredictRequest(api.ModelSpec("clf"), batch()))
        after = srv.tenancy.snapshot("default")["default"]["served"]
        assert after == before + 1

    def test_get_tenant_stats_rpc_and_http_get(self, stack):
        srv, http, client = stack
        client.predict(api.PredictRequest(
            api.ModelSpec("clf"), batch(),
            context=RequestContext(tenant="statsy")))
        resp = client.get_tenant_stats(api.GetTenantStatsRequest())
        by_name = {t.tenant: t for t in resp.tenants}
        assert by_name["statsy"].served >= 1
        assert "default" in by_name
        # filtered, over GET (curl-able)
        status, body = http_request(
            http.address, "GET", "/v1/tenants?tenant=statsy")
        assert status == 200
        assert [t["tenant"] for t in body["tenants"]] == ["statsy"]
        assert body["tenants"][0]["served"] >= 1
        status, body = http_request(http.address, "GET", "/v1/tenants")
        assert status == 200
        assert {t["tenant"] for t in body["tenants"]} >= {"statsy",
                                                          "default"}

    def test_generate_accounts_tokens_per_tenant(self, stack):
        srv, _, client = stack
        toks = batch(b=1, s=8, seed=7)["tokens"][0].astype(np.int32)
        resp = client.generate(api.GenerateRequest(
            api.ModelSpec("clf"), tokens=toks, max_new=4,
            context=RequestContext(tenant="gen-t")))
        assert resp.tokens.shape == (1, 4)
        snap = srv.tenancy.snapshot("gen-t")["gen-t"]
        assert snap["tokens_generated"] == 4
        assert snap["served"] == 1
        assert snap["decodes_inflight"] == 0 and snap["blocks_held"] == 0

    def test_disconnect_mid_stream_returns_tenant_blocks(self, stack):
        """Client hangs up mid-stream: the tenant's reserved blocks and
        decode slot must drain back to zero (quota not leaked)."""
        srv, _, client = stack
        toks = batch(b=1, s=8, seed=8)["tokens"][0].astype(np.int32)
        srv.tenancy.set_quota("streamer",
                              TenantQuota(max_concurrent_decodes=2))
        it = client.generate(api.GenerateRequest(
            api.ModelSpec("clf"), tokens=toks, max_new=400, stream=True,
            context=RequestContext(tenant="streamer")))
        assert next(it) is not None
        it.close()                           # disconnect
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            snap = srv.tenancy.snapshot("streamer")["streamer"]
            if (snap["decodes_inflight"] == 0
                    and snap["blocks_held"] == 0):
                break
            time.sleep(0.02)
        assert snap["decodes_inflight"] == 0
        assert snap["blocks_held"] == 0

    def test_decode_slot_quota_maps_to_429(self, stack):
        srv, http, _ = stack
        srv.tenancy.set_quota("nodecodes",
                              TenantQuota(max_concurrent_decodes=0))
        toks = batch(b=1, s=8, seed=9)["tokens"][0].astype(np.int32)
        status, body = http_request(
            http.address, "POST", "/v1/generate",
            wire.encode_message(api.GenerateRequest(
                api.ModelSpec("clf"), tokens=toks, max_new=4,
                context=RequestContext(tenant="nodecodes"))))
        assert status == 429
        assert body["error"]["code"] == "RESOURCE_EXHAUSTED"

    def test_call_envelope_carries_context(self, stack):
        srv, _, client = stack
        out = client.call(api.ModelSpec("clf"), "predict", batch(),
                          context=RequestContext(tenant="enveloped"))
        assert np.asarray(out).shape[0] == 2
        assert srv.tenancy.snapshot(
            "enveloped")["enveloped"]["served"] >= 1
