"""Optional-hypothesis shim for the property-based tests.

The seed suite hard-imported ``hypothesis`` in 6 modules, so a missing
dev dependency broke *collection* of the whole tier-1 suite. Importing
``given``/``settings``/``st`` from here instead degrades gracefully:
with hypothesis installed the real objects are re-exported; without it,
``@given`` turns each property test into an individual skip (the rest
of the module still runs — strictly better than the module-wide skip a
bare ``pytest.importorskip("hypothesis")`` would give).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # degrade to per-test skips
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed")(fn)
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategyStub:
        """st.<anything>(...) placeholder; never executed (skipped)."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
