"""Per-architecture smoke tests (deliverable f) + model invariants.

Every assigned architecture instantiates its REDUCED smoke variant
(≤2 layers, d_model ≤ 512, ≤4 experts), runs one forward/train step on
CPU, and asserts output shapes + no NaNs. Prefill+decode must agree with
the full-sequence forward in f32 (the serving-consistency invariant).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, SMOKE_ARCHS
from repro.models import model as MD
from repro.training.optimizer import AdamWConfig
from repro.training.train import init_train_state, make_train_step

B, S = 2, 16


def make_batch(cfg, rng, b=B, s=S, labels=True):
    batch = {}
    if cfg.input_kind == "embeddings":
        batch["embeds"] = jax.random.normal(
            rng, (b, s, cfg.d_model), jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(rng, (b, s), 0,
                                             cfg.vocab_size)
    if labels:
        batch["labels"] = jax.random.randint(rng, (b, s), 0,
                                             cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_shapes_no_nans(arch):
    cfg = SMOKE_ARCHS[arch]
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    rng = jax.random.PRNGKey(0)
    params = MD.init_params(rng, cfg)
    batch = make_batch(cfg, rng, labels=False)
    hidden, _, aux = MD.forward_hidden(params, cfg, batch, "train")
    logits = MD.logits_from_hidden(params, cfg, hidden)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_train_step(arch):
    """One real optimizer step on CPU: loss finite, params move."""
    cfg = SMOKE_ARCHS[arch]
    opt_cfg = AdamWConfig(learning_rate=1e-3, warmup_steps=0)
    rng = jax.random.PRNGKey(1)
    params, opt_state = init_train_state(rng, cfg, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    batch = make_batch(cfg, rng)
    new_params, new_opt, metrics = step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually changed
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree_util.tree_leaves(params),
                                jax.tree_util.tree_leaves(new_params)))
    assert delta > 0
    assert np.isfinite(float(metrics["grad_norm"]))


@pytest.mark.parametrize("arch", [a for a in ASSIGNED
                                  if SMOKE_ARCHS[a].causal])
def test_prefill_decode_matches_forward(arch):
    """Serving invariant: prefill+decode logits == full forward (f32)."""
    cfg = SMOKE_ARCHS[arch].with_overrides(
        dtype="float32", attn_chunk=8, ssm_chunk=8, mlstm_chunk=8,
        capacity_factor=float(max(SMOKE_ARCHS[arch].num_experts, 1)))
    rng = jax.random.PRNGKey(2)
    params = MD.init_params(rng, cfg)
    n_dec = 3
    toks = jax.random.randint(rng, (B, S + n_dec), 0, cfg.vocab_size)
    full = {"tokens": toks}
    if cfg.input_kind == "embeddings":
        emb = jnp.take(params["embed"], toks, axis=0).astype(jnp.float32)
        full = {"embeds": emb}

    hid, _, _ = MD.forward_hidden(params, cfg, full, "train")
    ref = MD.logits_from_hidden(params, cfg, hid)

    def sub(lo, hi):
        return ({"tokens": toks[:, lo:hi]} if "tokens" in full
                else {"embeds": full["embeds"][:, lo:hi]})

    cache = MD.init_cache(cfg, B, S + n_dec)
    lg, cache = MD.prefill(params, cfg, sub(0, S), cache)
    errs = [float(np.max(np.abs(lg - ref[:, S - 1])))]
    for t in range(n_dec):
        lg, cache = MD.decode_step(params, cfg, sub(S + t, S + t + 1),
                                   cache)
        errs.append(float(np.max(np.abs(lg - ref[:, S + t]))))
    assert max(errs) < 2e-3, errs


def test_sliding_window_ring_cache_long_prompt():
    """Danube family: prompt longer than the window — ring cache must
    match the full forward."""
    cfg = SMOKE_ARCHS["h2o-danube-3-4b"].with_overrides(
        dtype="float32", attn_chunk=8)
    assert cfg.window == 16
    rng = jax.random.PRNGKey(3)
    params = MD.init_params(rng, cfg)
    s = 3 * cfg.window  # prompt = 3 windows
    toks = jax.random.randint(rng, (1, s + 2), 0, cfg.vocab_size)
    hid, _, _ = MD.forward_hidden(params, cfg, {"tokens": toks}, "train")
    ref = MD.logits_from_hidden(params, cfg, hid)
    cache = MD.init_cache(cfg, 1, s + 2)
    lg, cache = MD.prefill(params, cfg, {"tokens": toks[:, :s]}, cache)
    errs = [float(np.max(np.abs(lg - ref[:, s - 1])))]
    for t in range(2):
        lg, cache = MD.decode_step(
            params, cfg, {"tokens": toks[:, s + t:s + t + 1]}, cache)
        errs.append(float(np.max(np.abs(lg - ref[:, s + t]))))
    assert max(errs) < 2e-3, errs


def test_encoder_is_bidirectional():
    """hubert: flipping a late frame must change early-frame logits."""
    cfg = SMOKE_ARCHS["hubert-xlarge"].with_overrides(dtype="float32")
    rng = jax.random.PRNGKey(4)
    params = MD.init_params(rng, cfg)
    emb = jax.random.normal(rng, (1, S, cfg.d_model))
    h1, _, _ = MD.forward_hidden(params, cfg, {"embeds": emb}, "train")
    emb2 = emb.at[:, -1].set(-emb[:, -1])
    h2, _, _ = MD.forward_hidden(params, cfg, {"embeds": emb2}, "train")
    assert float(jnp.max(jnp.abs(h1[:, 0] - h2[:, 0]))) > 1e-6


def test_decoder_is_causal():
    """Flipping a late token must NOT change earlier logits."""
    cfg = SMOKE_ARCHS["granite-8b"].with_overrides(dtype="float32",
                                                   attn_chunk=8)
    rng = jax.random.PRNGKey(5)
    params = MD.init_params(rng, cfg)
    toks = jax.random.randint(rng, (1, S), 0, cfg.vocab_size)
    h1, _, _ = MD.forward_hidden(params, cfg, {"tokens": toks}, "train")
    toks2 = toks.at[:, -1].set((toks[:, -1] + 1) % cfg.vocab_size)
    h2, _, _ = MD.forward_hidden(params, cfg, {"tokens": toks2}, "train")
    assert float(jnp.max(jnp.abs(h1[:, :-1] - h2[:, :-1]))) < 1e-5


def test_mrope_position_sensitivity():
    """qwen2-vl: distinct (t,h,w) positions change the output vs. all-
    equal positions (M-RoPE is actually wired through)."""
    cfg = SMOKE_ARCHS["qwen2-vl-72b"].with_overrides(dtype="float32",
                                                     attn_chunk=8)
    rng = jax.random.PRNGKey(6)
    params = MD.init_params(rng, cfg)
    emb = jax.random.normal(rng, (1, S, cfg.d_model))
    base = jnp.broadcast_to(jnp.arange(S)[None, :, None], (1, S, 3))
    h1, _, _ = MD.forward_hidden(
        params, cfg, {"embeds": emb, "positions": base}, "train")
    # image-patch style: same t, varying h/w
    pos2 = base.at[:, :, 1].set(jnp.arange(S)[::-1][None])
    h2, _, _ = MD.forward_hidden(
        params, cfg, {"embeds": emb, "positions": pos2}, "train")
    assert float(jnp.max(jnp.abs(h1 - h2))) > 1e-6


def test_moe_dropless_decode_and_capacity():
    """MoE decode is dropless; train-time drop fraction is reported."""
    cfg = SMOKE_ARCHS["qwen3-moe-30b-a3b"].with_overrides(
        dtype="float32", capacity_factor=0.5)
    rng = jax.random.PRNGKey(7)
    params = MD.init_params(rng, cfg)
    batch = make_batch(cfg, rng, labels=False)
    _, _, aux = MD.forward_hidden(params, cfg, batch, "train")
    assert float(aux["moe_drop_fraction"]) > 0  # cf=0.5 must drop
    cache = MD.init_cache(cfg, B, 8)
    _, cache = MD.prefill(params, cfg,
                          {"tokens": batch["tokens"][:, :4]}, cache)
    _, _, aux_dec = MD.forward_hidden(
        params, cfg, {"tokens": batch["tokens"][:, 4:5]}, "decode", cache)
    assert float(aux_dec["moe_drop_fraction"]) == 0.0


def test_param_counts_match_actual_params():
    """Analytic param accounting (Controller RAM estimates, roofline)
    agrees with real initialized trees."""
    for arch in ("granite-8b", "qwen3-moe-30b-a3b", "xlstm-125m",
                 "jamba-1.5-large-398b"):
        cfg = SMOKE_ARCHS[arch]
        params = MD.init_params(jax.random.PRNGKey(0), cfg)
        actual = sum(int(np.prod(l.shape))
                     for l in jax.tree_util.tree_leaves(params))
        # smoke variants of embedding-input models still allocate embed
        est = cfg.param_counts()["total"]
        if cfg.input_kind == "embeddings" and cfg.causal:
            est += cfg.vocab_size * cfg.d_model
        assert abs(actual - est) / actual < 0.05, (arch, actual, est)
