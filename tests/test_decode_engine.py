"""Continuous-batching decode engine: bit-exact equivalence with
per-request generate (paged AND contiguous KV layouts), slot reuse
under churn, block-pool admission/exhaustion, request cancellation,
sampling params, and the slot-oriented cache helpers."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as MD
from repro.serving.decode_engine import DecodeScheduler
from repro.serving.generation import SamplingParams, sample_token

CFG = get_config("tfs-classifier", smoke=True).with_overrides(
    dtype="float32")


@pytest.fixture(scope="module")
def params():
    return MD.init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def engine(params):
    # Paged by default: every pre-existing engine test now exercises the
    # block-pool layout against the per-request reference.
    eng = DecodeScheduler(CFG, params, num_slots=4, max_seq_len=64)
    assert eng.paged
    eng.start()
    yield eng
    eng.stop()


def reference_generate(params, tokens, max_new):
    """Per-request greedy decode, the sequential baseline semantics."""
    cache = MD.init_cache(CFG, 1, tokens.shape[0] + max_new)
    logits, cache = MD.prefill(params, CFG, {"tokens": tokens[None]},
                               cache)
    out = [int(np.argmax(np.asarray(logits)[0]))]
    for _ in range(max_new - 1):
        logits, cache = MD.decode_step(
            params, CFG, {"tokens": np.asarray([[out[-1]]])}, cache)
        out.append(int(np.argmax(np.asarray(logits)[0])))
    return np.asarray(out, np.int32)


class TestDecodeScheduler:
    def test_single_request_bit_identical(self, engine, params):
        rng = np.random.default_rng(0)
        toks = rng.integers(0, CFG.vocab_size, 16).astype(np.int32)
        got = engine.generate(toks, max_new=6)
        np.testing.assert_array_equal(
            got, reference_generate(params, toks, 6))

    def test_churn_more_requests_than_slots(self, engine, params):
        """Mixed lengths + mixed max_new through 4 slots: retired slots
        must backfill and every output stay bit-identical."""
        rng = np.random.default_rng(1)
        prompts = [rng.integers(0, CFG.vocab_size, int(n)).astype(np.int32)
                   for n in rng.integers(4, 24, 10)]
        max_news = [int(m) for m in rng.integers(1, 9, 10)]
        reqs = [engine.submit(p, m) for p, m in zip(prompts, max_news)]
        outs = [r.wait(120) for r in reqs]
        for out, p, m in zip(outs, prompts, max_news):
            np.testing.assert_array_equal(
                out, reference_generate(params, p, m))
        assert engine.active_slots() == 0        # all slots freed
        assert engine.stats["finished"] >= 10

    def test_concurrent_clients_share_ticks(self, engine, params):
        """N threads with the same max_new should batch into roughly
        max_new ticks, not N * max_new."""
        rng = np.random.default_rng(2)
        prompts = [rng.integers(0, CFG.vocab_size, 12).astype(np.int32)
                   for _ in range(4)]
        engine.generate(prompts[0], max_new=6)   # warm the compiles
        ticks_before = engine.stats["ticks"]
        results = [None] * 4

        def client(i):
            results[i] = engine.generate(prompts[i], max_new=6)
        ts = [threading.Thread(target=client, args=(i,))
              for i in range(4)]
        [t.start() for t in ts]
        [t.join() for t in ts]
        for i in range(4):
            np.testing.assert_array_equal(
                results[i], reference_generate(params, prompts[i], 6))
        # 4 concurrent requests of 5 decode steps each: far fewer ticks
        # than the 20 a serialized engine would need
        assert engine.stats["ticks"] - ticks_before < 20

    def test_eos_retires_slot_early(self, params):
        eng = DecodeScheduler(CFG, params, num_slots=2, max_seq_len=64)
        eng.start()
        try:
            toks = np.arange(10, dtype=np.int32)
            full = eng.generate(toks, max_new=8)
            # Retune eos only while the engine thread is joined: a bare
            # write races the engine's per-step eos check (the lockset
            # detector flags it), and stop()/start() are cheap.
            eng.stop()
            eng.eos = int(full[1])
            eng.start()
            out = eng.generate(toks, max_new=8)
            assert out.shape[0] <= 2 or eng.eos not in out[:-1]
            assert eng.active_slots() == 0
        finally:
            eng.stop()

    def test_sampling_deterministic_per_seed(self, engine):
        toks = np.arange(9, dtype=np.int32)
        sp = SamplingParams(temperature=0.7, top_k=16, seed=123)
        a = engine.generate(toks, max_new=8, sampling=sp)
        b = engine.generate(toks, max_new=8, sampling=sp)
        np.testing.assert_array_equal(a, b)

    def test_top_k_one_equals_greedy(self, engine, params):
        toks = np.arange(11, dtype=np.int32)
        sp = SamplingParams(temperature=1.0, top_k=1, seed=7)
        np.testing.assert_array_equal(
            engine.generate(toks, max_new=6, sampling=sp),
            reference_generate(params, toks, 6))

    def test_submit_validates_budget(self, engine):
        with pytest.raises(ValueError):
            engine.submit(np.arange(60, dtype=np.int32), max_new=10)
        with pytest.raises(ValueError):
            engine.submit(np.arange(4, dtype=np.int32), max_new=0)

    def test_stop_fails_inflight_requests(self, params):
        eng = DecodeScheduler(CFG, params, num_slots=2, max_seq_len=64)
        eng.start()
        req = eng.submit(np.arange(8, dtype=np.int32), max_new=8)
        eng.stop()
        with pytest.raises((RuntimeError, TimeoutError)):
            req.wait(1.0)
        with pytest.raises(RuntimeError):
            eng.submit(np.arange(8, dtype=np.int32), max_new=2)


class TestPagedEngine:
    def test_paged_vs_contiguous_bit_identical_staggered(self, params):
        """Same staggered-length workload through a paged and a
        contiguous engine: greedy outputs must match bit-for-bit (and
        the per-request reference)."""
        rng = np.random.default_rng(7)
        prompts = [rng.integers(0, CFG.vocab_size, int(n)).astype(np.int32)
                   for n in rng.integers(4, 25, 8)]
        max_news = [int(m) for m in rng.integers(1, 9, 8)]
        paged = DecodeScheduler(CFG, params, num_slots=3, max_seq_len=64,
                                paged=True, block_size=8)
        cont = DecodeScheduler(CFG, params, num_slots=3, max_seq_len=64,
                               paged=False)
        paged.start()
        cont.start()
        try:
            pr = [paged.submit(p, m) for p, m in zip(prompts, max_news)]
            cr = [cont.submit(p, m) for p, m in zip(prompts, max_news)]
            for i, (a, b) in enumerate(zip(pr, cr)):
                out_p, out_c = a.wait(120), b.wait(120)
                np.testing.assert_array_equal(out_p, out_c)
                np.testing.assert_array_equal(
                    out_p, reference_generate(params, prompts[i],
                                              max_news[i]))
            assert paged.active_slots() == 0
            # every block returned to the free list
            assert paged.free_block_count() == paged.num_blocks - 1
        finally:
            paged.stop()
            cont.stop()

    def test_block_exhaustion_queue_waits(self, params):
        """More requests than the block pool admits at once: admission
        waits at the head of the queue (no crash, no starvation) and
        every output stays exact."""
        # need = ceil((12 + 8 - 1) / 8) = 3 blocks per request; 6 usable
        # blocks => exactly 2 concurrent although there are 4 slots.
        eng = DecodeScheduler(CFG, params, num_slots=4, max_seq_len=64,
                              paged=True, block_size=8, num_blocks=7)
        prompts = [np.arange(i, i + 12, dtype=np.int32) % CFG.vocab_size
                   for i in range(5)]
        reqs = [eng.submit(p, 8) for p in prompts]   # queued pre-start
        eng.start()
        try:
            outs = [r.wait(120) for r in reqs]
            for out, p in zip(outs, prompts):
                np.testing.assert_array_equal(
                    out, reference_generate(params, p, 8))
            stats = eng.stats
            assert stats["admission_waits"] >= 1
            assert stats["finished"] == 5
            assert eng.active_slots() == 0
            assert eng.free_block_count() == 6
        finally:
            eng.stop()

    def test_cancel_frees_blocks(self, params):
        """A cancelled (abandoned) request retires its slot at the next
        tick and returns its blocks to the free list."""
        eng = DecodeScheduler(CFG, params, num_slots=2, max_seq_len=64,
                              paged=True, block_size=8)
        eng.start()
        usable = eng.num_blocks - 1
        try:
            req = eng.submit(np.arange(8, dtype=np.int32), max_new=48)
            deadline = time.monotonic() + 30
            while eng.active_slots() == 0 and time.monotonic() < deadline:
                time.sleep(0.005)
            assert eng.active_slots() == 1
            assert eng.free_block_count() < usable
            eng.cancel(req)
            while ((eng.active_slots() or
                    eng.free_block_count() != usable) and
                   time.monotonic() < deadline):
                time.sleep(0.005)
            assert eng.active_slots() == 0
            assert eng.free_block_count() == usable
            assert eng.stats["cancelled"] >= 1
            with pytest.raises(RuntimeError, match="cancelled"):
                req.wait(10)
            # the engine keeps serving exactly after a cancellation
            toks = np.arange(9, dtype=np.int32)
            np.testing.assert_array_equal(
                eng.generate(toks, max_new=4),
                reference_generate(params, toks, 4))
        finally:
            eng.stop()

    def test_generate_timeout_cancels(self, params):
        """generate() that times out marks its request abandoned so the
        engine reclaims the slot instead of decoding to max_new."""
        eng = DecodeScheduler(CFG, params, num_slots=2, max_seq_len=64,
                              paged=True, block_size=8)
        eng.start()
        try:
            with pytest.raises(TimeoutError):
                eng.generate(np.arange(6, dtype=np.int32), max_new=40,
                             timeout=0.0)
            deadline = time.monotonic() + 30
            while ((eng.active_slots() or
                    eng.free_block_count() != eng.num_blocks - 1) and
                   time.monotonic() < deadline):
                time.sleep(0.005)
            assert eng.active_slots() == 0
            assert eng.free_block_count() == eng.num_blocks - 1
        finally:
            eng.stop()

    def test_submit_validates_block_budget(self, params):
        eng = DecodeScheduler(CFG, params, num_slots=2, max_seq_len=64,
                              paged=True, block_size=8, num_blocks=3)
        # 2 usable blocks = 16 positions; prompt 20 + max_new 8 passes
        # the max_seq_len check but can never be paged in.
        with pytest.raises(ValueError, match="blocks"):
            eng.submit(np.arange(20, dtype=np.int32), max_new=8)
        assert not eng.admits(20, 8)
        assert eng.admits(8, 8)

    def test_stats_snapshot_under_concurrent_readers(self, engine):
        """stats/active_slots snapshot under the engine lock: a reader
        hammering them during a burst must never see torn state (e.g.
        finished > requests) or crash."""
        stop = threading.Event()
        torn = []

        def reader():
            while not stop.is_set():
                s = engine.stats
                if s["finished"] > s["requests"]:
                    torn.append(s)
                engine.active_slots()

        t = threading.Thread(target=reader)
        t.start()
        try:
            rng = np.random.default_rng(3)
            prompts = [rng.integers(0, CFG.vocab_size, 8).astype(np.int32)
                       for _ in range(6)]
            reqs = [engine.submit(p, 4) for p in prompts]
            [r.wait(120) for r in reqs]
        finally:
            stop.set()
            t.join(timeout=10)
        assert not torn


class TestPagedCacheHelpers:
    def test_insert_scatters_blocks_and_table(self, params):
        pool = MD.init_paged_cache(CFG, 3, 32, block_size=8)
        assert pool["tables"].shape == (3, 4)
        row = MD.init_cache(CFG, 1, 32)
        toks = np.arange(11, dtype=np.int32)
        _, row = MD.prefill(params, CFG, {"tokens": toks[None]}, row)
        pool = MD.cache_insert_slot_paged(
            CFG, pool, row, 1, jnp.asarray([4, 2], jnp.int32))
        np.testing.assert_array_equal(np.asarray(pool["len"]), [0, 11, 0])
        np.testing.assert_array_equal(np.asarray(pool["tables"])[1],
                                      [4, 2, -1, -1])
        pos = np.asarray(pool["layers"]["s0"]["pos"])
        np.testing.assert_array_equal(pos[0, 4], np.arange(8))
        np.testing.assert_array_equal(pos[0, 2, :3], [8, 9, 10])
        assert np.all(pos[0, 2, 3:] == -1)
        assert np.all(pos[0, 1] == -1)           # unassigned untouched

    def test_release_detaches_table_only(self, params):
        pool = MD.init_paged_cache(CFG, 2, 32, block_size=8)
        row = MD.init_cache(CFG, 1, 32)
        toks = np.arange(5, dtype=np.int32)
        _, row = MD.prefill(params, CFG, {"tokens": toks[None]}, row)
        pool = MD.cache_insert_slot_paged(
            CFG, pool, row, 0, jnp.asarray([1], jnp.int32))
        pool = MD.cache_insert_slot_paged(
            CFG, pool, row, 1, jnp.asarray([3], jnp.int32))
        pool = MD.cache_release_slot_paged(pool, 0)
        np.testing.assert_array_equal(
            np.asarray(pool["tables"]),
            [[-1, -1, -1, -1], [3, -1, -1, -1]])
        # neighbor's blocks untouched by the release
        pos = np.asarray(pool["layers"]["s0"]["pos"])
        assert np.any(pos[0, 3] >= 0)

    def test_estimate_scales_with_blocks_not_capacity(self):
        full = MD.estimate_paged_cache_bytes(CFG, 8, 512)
        half = MD.estimate_paged_cache_bytes(
            CFG, 8, 512, num_blocks=MD.default_num_blocks(8, 512) // 2)
        contiguous = MD.estimate_pool_cache_bytes(CFG, 8, 512)
        assert half < full
        assert abs(full - contiguous) / contiguous < 0.05
        with pytest.raises(ValueError, match="window"):
            MD.init_paged_cache(CFG.with_overrides(window=16), 2, 32)

    def test_windowed_config_falls_back_to_contiguous(self, params):
        eng = DecodeScheduler(CFG.with_overrides(window=16), params,
                              num_slots=2, max_seq_len=32)
        assert not eng.paged


class TestSlotCacheHelpers:
    def test_insert_sets_row_and_length(self, params):
        pool = MD.init_pool_cache(CFG, 3, 32)
        assert pool["len"].shape == (3,)
        row = MD.init_cache(CFG, 1, 32)
        toks = np.arange(7, dtype=np.int32)
        _, row = MD.prefill(params, CFG, {"tokens": toks[None]}, row)
        pool = MD.cache_insert_slot(pool, row, 1)
        np.testing.assert_array_equal(np.asarray(pool["len"]), [0, 7, 0])
        k_pool = np.asarray(
            jax.tree_util.tree_leaves(pool["layers"])[0])
        assert not np.all(k_pool[:, 1] == 0)     # row 1 got the prefill
        assert np.all(k_pool[:, 0] == 0)         # neighbors untouched

    def test_reset_clears_one_slot_only(self, params):
        pool = MD.init_pool_cache(CFG, 2, 32)
        toks = np.arange(5, dtype=np.int32)
        for slot in (0, 1):
            row = MD.init_cache(CFG, 1, 32)
            _, row = MD.prefill(params, CFG, {"tokens": toks[None]}, row)
            pool = MD.cache_insert_slot(pool, row, slot)
        pool = MD.cache_reset_slot(CFG, pool, 0, 32)
        np.testing.assert_array_equal(np.asarray(pool["len"]), [0, 5])
        pos = np.asarray(pool["layers"]["s0"]["pos"])
        assert np.all(pos[:, 0] == -1)           # slot 0 invalidated
        assert np.any(pos[:, 1] >= 0)            # slot 1 intact

    def test_per_row_decode_positions_independent(self, params):
        """Two slots at different lengths must each write their K/V at
        their own ring position during a fused step."""
        pool = MD.init_pool_cache(CFG, 2, 32)
        for slot, n in ((0, 4), (1, 9)):
            row = MD.init_cache(CFG, 1, 32)
            toks = np.arange(n, dtype=np.int32)
            _, row = MD.prefill(params, CFG, {"tokens": toks[None]}, row)
            pool = MD.cache_insert_slot(pool, row, slot)
        _, pool = MD.decode_step(
            params, CFG, {"tokens": jnp.asarray([[1], [2]])}, pool)
        np.testing.assert_array_equal(np.asarray(pool["len"]), [5, 10])
        pos = np.asarray(pool["layers"]["s0"]["pos"])
        assert np.all(pos[:, 0, 4] == 4) and np.all(pos[:, 1, 9] == 9)
        assert np.all(pos[:, 0, 5:] == -1)       # nothing written beyond


def test_sample_token_greedy_and_top_k():
    logits = np.asarray([0.1, 3.0, 2.0, -1.0], np.float32)
    assert sample_token(logits, None) == 1
    assert sample_token(logits, SamplingParams()) == 1
    sp = SamplingParams(temperature=1.0, top_k=2, seed=0)
    picks = {sample_token(logits, sp, np.random.default_rng(s))
             for s in range(50)}
    assert picks <= {1, 2}                       # never outside top-2


class TestPagedPrefill:
    """Prefill-into-blocks (no staging row) + chunked prefill."""

    def test_prefill_paged_matches_staging_insert_exactly(self, params):
        """prefill_paged must leave the pool in EXACTLY the state the
        old staging-row + cache_insert_slot_paged path produced —
        logits, lengths, tables, and every cache leaf."""
        toks = np.arange(13, dtype=np.int32) % CFG.vocab_size
        bs, max_seq = 8, 64
        bps, _ = MD.paged_layout(max_seq, bs)
        need = -(-(13 + 6 - 1) // bs)
        blocks = np.arange(2, 2 + need, dtype=np.int32)

        pool_a = MD.init_paged_cache(CFG, 3, max_seq, block_size=bs)
        row = MD.init_cache(CFG, 1, max_seq)
        logits_a, row = MD.prefill(params, CFG, {"tokens": toks[None]},
                                   row)
        pool_a = MD.cache_insert_slot_paged(CFG, pool_a, row, 1,
                                            jnp.asarray(blocks))

        pool_b = MD.init_paged_cache(CFG, 3, max_seq, block_size=bs)
        table_row = np.full(bps, -1, np.int32)
        table_row[:need] = blocks
        logits_b, pool_b = MD.prefill_paged(
            params, CFG, {"tokens": toks[None]}, pool_b, 1, table_row, 0,
            fresh=True)

        np.testing.assert_array_equal(np.asarray(logits_a),
                                      np.asarray(logits_b))
        for leaf_a, leaf_b in zip(
                jax.tree_util.tree_leaves(pool_a),
                jax.tree_util.tree_leaves(pool_b)):
            np.testing.assert_array_equal(np.asarray(leaf_a),
                                          np.asarray(leaf_b))

    def test_fresh_prefill_invalidates_stale_positions(self, params):
        """A reused block still carrying a previous occupant's positions
        must come back invalid (-1) after a fresh prefill assigns it —
        beyond the new prompt's extent — or the gathered validity mask
        would resurrect dead tokens."""
        bs, max_seq = 8, 64
        bps, _ = MD.paged_layout(max_seq, bs)
        pool = MD.init_paged_cache(CFG, 2, max_seq, block_size=bs)
        # occupant A: 16 tokens across blocks [3, 4]
        row_a = np.full(bps, -1, np.int32)
        row_a[:2] = [3, 4]
        toksa = np.arange(16, dtype=np.int32) % CFG.vocab_size
        _, pool = MD.prefill_paged(params, CFG, {"tokens": toksa[None]},
                                   pool, 0, row_a, 0, fresh=True)
        pos = np.asarray(pool["layers"]["s0"]["pos"])
        assert np.all(pos[:, 4] >= 0)            # block 4 fully written
        # occupant B reuses blocks [4, 3] (reversed!) for a 5-token
        # prompt: block 3 (logical 1) is assigned-but-unwritten and must
        # be invalidated, not keep A's stale positions.
        row_b = np.full(bps, -1, np.int32)
        row_b[:2] = [4, 3]
        toksb = np.arange(5, dtype=np.int32) % CFG.vocab_size
        _, pool = MD.prefill_paged(params, CFG, {"tokens": toksb[None]},
                                   pool, 1, row_b, 0, fresh=True)
        pos = np.asarray(pool["layers"]["s0"]["pos"])
        np.testing.assert_array_equal(pos[0, 4, :5], np.arange(5))
        assert np.all(pos[0, 4, 5:] == -1)
        assert np.all(pos[0, 3] == -1)           # stale A positions gone

    def test_chunked_prefill_greedy_identical(self, params):
        """prefill_chunk splits long prompts across ticks; greedy
        outputs must match the whole-prompt engine and the per-request
        reference."""
        rng = np.random.default_rng(11)
        prompts = [rng.integers(0, CFG.vocab_size, int(n)).astype(np.int32)
                   for n in rng.integers(3, 30, 8)]
        max_news = [int(m) for m in rng.integers(1, 8, 8)]
        eng = DecodeScheduler(CFG, params, num_slots=3, max_seq_len=64,
                              paged=True, block_size=8, prefill_chunk=6)
        eng.start()
        try:
            reqs = [eng.submit(p, m) for p, m in zip(prompts, max_news)]
            outs = [r.wait(240) for r in reqs]
            for out, p, m in zip(outs, prompts, max_news):
                np.testing.assert_array_equal(
                    out, reference_generate(params, p, m))
            stats = eng.stats
            assert stats["prefill_chunks"] > 0       # chunking engaged
            assert eng.free_block_count() == eng.num_blocks - 1
        finally:
            eng.stop()

    def test_chunked_prefill_interleaves_ticks(self, params):
        """While a long prompt chunk-prefills, an already-active slot
        must keep receiving decode ticks (the head-of-line bound)."""
        eng = DecodeScheduler(CFG, params, num_slots=2, max_seq_len=128,
                              paged=True, block_size=8, prefill_chunk=4)
        eng.start()
        try:
            seen_during = []
            long_prompt = (np.arange(48, dtype=np.int32)
                           % CFG.vocab_size)
            active = eng.submit(np.arange(6, dtype=np.int32), max_new=60,
                                on_token=lambda i, t:
                                seen_during.append(i))
            deadline = time.monotonic() + 60
            while eng.active_slots() == 0 and time.monotonic() < deadline:
                time.sleep(0.002)
            late = eng.submit(long_prompt, max_new=2)
            late.wait(240)
            # 48/4 = 12 chunk passes ran; the active slot must have
            # decoded during them, not stalled until the prefill ended.
            assert len(seen_during) > 2
            active.cancel()
        finally:
            eng.stop()

    def test_prefill_chunk_validation(self, params):
        with pytest.raises(ValueError, match="paged"):
            DecodeScheduler(CFG, params, num_slots=2, max_seq_len=32,
                            paged=False, prefill_chunk=8)
        with pytest.raises(ValueError, match=">= 1"):
            DecodeScheduler(CFG, params, num_slots=2, max_seq_len=32,
                            prefill_chunk=0)

    def test_pallas_paged_kernel_engine_matches_xla(self, params):
        """The same workload through a pallas(-interpret) engine — the
        paged-attention kernel walking block tables — and the XLA
        gathered-view engine: greedy outputs bit-identical."""
        cfg_p = CFG.with_overrides(attention_impl="pallas_interpret")
        rng = np.random.default_rng(17)
        prompts = [rng.integers(0, CFG.vocab_size, int(n)).astype(np.int32)
                   for n in rng.integers(4, 20, 3)]
        ep = DecodeScheduler(cfg_p, params, num_slots=2, max_seq_len=64,
                             paged=True, block_size=16)
        ex = DecodeScheduler(CFG, params, num_slots=2, max_seq_len=64,
                             paged=True, block_size=16)
        ep.start()
        ex.start()
        try:
            rp = [ep.submit(p, 4) for p in prompts]
            rx = [ex.submit(p, 4) for p in prompts]
            for a, b in zip(rp, rx):
                np.testing.assert_array_equal(a.wait(300), b.wait(300))
        finally:
            ep.stop()
            ex.stop()


class TestCancelledActiveSlot:
    def test_no_tokens_after_cancel(self, params):
        """A cancelled ACTIVE slot must stop emitting immediately: the
        tick that observes the cancel retires the slot instead of
        emitting its sampled token (a disconnected stream must never
        receive post-cancel tokens)."""
        eng = DecodeScheduler(CFG, params, num_slots=2, max_seq_len=128,
                              paged=True, block_size=8)
        eng.start()
        emitted = []
        box = {}

        def on_token(i, t):
            emitted.append(i)
            if i == 1:
                box["req"].cancel()      # cancel from mid-decode

        try:
            req = eng.submit(np.arange(8, dtype=np.int32), max_new=60,
                             on_token=on_token)
            box["req"] = req
            with pytest.raises(RuntimeError, match="cancelled"):
                req.wait(120)
            time.sleep(0.2)              # give stray ticks a chance
            assert emitted == [0, 1], emitted
            assert eng.active_slots() == 0
            assert eng.free_block_count() == eng.num_blocks - 1
            assert eng.stats["cancelled"] >= 1
        finally:
            eng.stop()

    def test_on_token_cancel_from_callback_is_immediate(self, params):
        """Cancelling from within the on_token tap (how a transport
        reacts to a disconnect it notices while writing a chunk) stops
        emission at exactly that token."""
        eng = DecodeScheduler(CFG, params, num_slots=2, max_seq_len=128,
                              paged=True, block_size=8)
        eng.start()
        tokens = []
        box = {}

        def on_token(i, t):
            tokens.append((i, t))
            box["req"].cancel()

        try:
            req = eng.submit(np.arange(5, dtype=np.int32), max_new=40,
                             on_token=on_token)
            box["req"] = req
            with pytest.raises(RuntimeError, match="cancelled"):
                req.wait(120)
            time.sleep(0.2)
            assert [i for i, _ in tokens] == [0], tokens
            assert eng.free_block_count() == eng.num_blocks - 1
        finally:
            eng.stop()


class _ExplodingSampling:
    """Truthy sampling stand-in whose make_rng raises: injects a crash
    between the free-list pop and the slot publish in ``_backfill``."""

    def make_rng(self):
        raise RuntimeError("injected mid-admission failure")


class TestOrphanedReservationReclaim:
    def test_blocks_reclaimed_after_mid_admission_crash(self, params):
        """A failure after blocks are popped but before the slot
        publishes used to orphan the reservation forever (neither the
        tick-crash handler nor stop() saw it in a slot). The ledger now
        records ownership at the pop, so the engine-loop handler
        reclaims it and the pool returns to full."""
        eng = DecodeScheduler(CFG, params, num_slots=2, max_seq_len=64)
        eng.start()
        try:
            total = eng.free_block_count()
            toks = np.arange(6, dtype=np.int32) % CFG.vocab_size
            eng.submit(toks, max_new=4, sampling=_ExplodingSampling())
            # Admitted strictly after the crash above: its completion
            # orders the reclaim check after the injected failure.
            out = eng.generate(toks, max_new=3)
            assert out.shape[0] == 3       # engine survived the crash
            deadline = time.monotonic() + 20
            while (eng.free_block_count() != total
                   and time.monotonic() < deadline):
                time.sleep(0.01)
            assert eng.free_block_count() == total
        finally:
            eng.stop()
