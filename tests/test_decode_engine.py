"""Continuous-batching decode engine: bit-exact equivalence with
per-request generate, slot reuse under churn, sampling params, and the
slot-oriented cache helpers."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as MD
from repro.serving.decode_engine import DecodeScheduler
from repro.serving.generation import SamplingParams, sample_token

CFG = get_config("tfs-classifier", smoke=True).with_overrides(
    dtype="float32")


@pytest.fixture(scope="module")
def params():
    return MD.init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def engine(params):
    eng = DecodeScheduler(CFG, params, num_slots=4, max_seq_len=64)
    eng.start()
    yield eng
    eng.stop()


def reference_generate(params, tokens, max_new):
    """Per-request greedy decode, the sequential baseline semantics."""
    cache = MD.init_cache(CFG, 1, tokens.shape[0] + max_new)
    logits, cache = MD.prefill(params, CFG, {"tokens": tokens[None]},
                               cache)
    out = [int(np.argmax(np.asarray(logits)[0]))]
    for _ in range(max_new - 1):
        logits, cache = MD.decode_step(
            params, CFG, {"tokens": np.asarray([[out[-1]]])}, cache)
        out.append(int(np.argmax(np.asarray(logits)[0])))
    return np.asarray(out, np.int32)


class TestDecodeScheduler:
    def test_single_request_bit_identical(self, engine, params):
        rng = np.random.default_rng(0)
        toks = rng.integers(0, CFG.vocab_size, 16).astype(np.int32)
        got = engine.generate(toks, max_new=6)
        np.testing.assert_array_equal(
            got, reference_generate(params, toks, 6))

    def test_churn_more_requests_than_slots(self, engine, params):
        """Mixed lengths + mixed max_new through 4 slots: retired slots
        must backfill and every output stay bit-identical."""
        rng = np.random.default_rng(1)
        prompts = [rng.integers(0, CFG.vocab_size, int(n)).astype(np.int32)
                   for n in rng.integers(4, 24, 10)]
        max_news = [int(m) for m in rng.integers(1, 9, 10)]
        reqs = [engine.submit(p, m) for p, m in zip(prompts, max_news)]
        outs = [r.wait(120) for r in reqs]
        for out, p, m in zip(outs, prompts, max_news):
            np.testing.assert_array_equal(
                out, reference_generate(params, p, m))
        assert engine.active_slots() == 0        # all slots freed
        assert engine.stats["finished"] >= 10

    def test_concurrent_clients_share_ticks(self, engine, params):
        """N threads with the same max_new should batch into roughly
        max_new ticks, not N * max_new."""
        rng = np.random.default_rng(2)
        prompts = [rng.integers(0, CFG.vocab_size, 12).astype(np.int32)
                   for _ in range(4)]
        engine.generate(prompts[0], max_new=6)   # warm the compiles
        ticks_before = engine.stats["ticks"]
        results = [None] * 4

        def client(i):
            results[i] = engine.generate(prompts[i], max_new=6)
        ts = [threading.Thread(target=client, args=(i,))
              for i in range(4)]
        [t.start() for t in ts]
        [t.join() for t in ts]
        for i in range(4):
            np.testing.assert_array_equal(
                results[i], reference_generate(params, prompts[i], 6))
        # 4 concurrent requests of 5 decode steps each: far fewer ticks
        # than the 20 a serialized engine would need
        assert engine.stats["ticks"] - ticks_before < 20

    def test_eos_retires_slot_early(self, params):
        eng = DecodeScheduler(CFG, params, num_slots=2, max_seq_len=64)
        eng.start()
        try:
            toks = np.arange(10, dtype=np.int32)
            full = eng.generate(toks, max_new=8)
            eng.eos = int(full[1])
            out = eng.generate(toks, max_new=8)
            assert out.shape[0] <= 2 or eng.eos not in out[:-1]
            assert eng.active_slots() == 0
        finally:
            eng.stop()

    def test_sampling_deterministic_per_seed(self, engine):
        toks = np.arange(9, dtype=np.int32)
        sp = SamplingParams(temperature=0.7, top_k=16, seed=123)
        a = engine.generate(toks, max_new=8, sampling=sp)
        b = engine.generate(toks, max_new=8, sampling=sp)
        np.testing.assert_array_equal(a, b)

    def test_top_k_one_equals_greedy(self, engine, params):
        toks = np.arange(11, dtype=np.int32)
        sp = SamplingParams(temperature=1.0, top_k=1, seed=7)
        np.testing.assert_array_equal(
            engine.generate(toks, max_new=6, sampling=sp),
            reference_generate(params, toks, 6))

    def test_submit_validates_budget(self, engine):
        with pytest.raises(ValueError):
            engine.submit(np.arange(60, dtype=np.int32), max_new=10)
        with pytest.raises(ValueError):
            engine.submit(np.arange(4, dtype=np.int32), max_new=0)

    def test_stop_fails_inflight_requests(self, params):
        eng = DecodeScheduler(CFG, params, num_slots=2, max_seq_len=64)
        eng.start()
        req = eng.submit(np.arange(8, dtype=np.int32), max_new=8)
        eng.stop()
        with pytest.raises((RuntimeError, TimeoutError)):
            req.wait(1.0)
        with pytest.raises(RuntimeError):
            eng.submit(np.arange(8, dtype=np.int32), max_new=2)


class TestSlotCacheHelpers:
    def test_insert_sets_row_and_length(self, params):
        pool = MD.init_pool_cache(CFG, 3, 32)
        assert pool["len"].shape == (3,)
        row = MD.init_cache(CFG, 1, 32)
        toks = np.arange(7, dtype=np.int32)
        _, row = MD.prefill(params, CFG, {"tokens": toks[None]}, row)
        pool = MD.cache_insert_slot(pool, row, 1)
        np.testing.assert_array_equal(np.asarray(pool["len"]), [0, 7, 0])
        k_pool = np.asarray(
            jax.tree_util.tree_leaves(pool["layers"])[0])
        assert not np.all(k_pool[:, 1] == 0)     # row 1 got the prefill
        assert np.all(k_pool[:, 0] == 0)         # neighbors untouched

    def test_reset_clears_one_slot_only(self, params):
        pool = MD.init_pool_cache(CFG, 2, 32)
        toks = np.arange(5, dtype=np.int32)
        for slot in (0, 1):
            row = MD.init_cache(CFG, 1, 32)
            _, row = MD.prefill(params, CFG, {"tokens": toks[None]}, row)
            pool = MD.cache_insert_slot(pool, row, slot)
        pool = MD.cache_reset_slot(CFG, pool, 0, 32)
        np.testing.assert_array_equal(np.asarray(pool["len"]), [0, 5])
        pos = np.asarray(pool["layers"]["s0"]["pos"])
        assert np.all(pos[:, 0] == -1)           # slot 0 invalidated
        assert np.any(pos[:, 1] >= 0)            # slot 1 intact

    def test_per_row_decode_positions_independent(self, params):
        """Two slots at different lengths must each write their K/V at
        their own ring position during a fused step."""
        pool = MD.init_pool_cache(CFG, 2, 32)
        for slot, n in ((0, 4), (1, 9)):
            row = MD.init_cache(CFG, 1, 32)
            toks = np.arange(n, dtype=np.int32)
            _, row = MD.prefill(params, CFG, {"tokens": toks[None]}, row)
            pool = MD.cache_insert_slot(pool, row, slot)
        _, pool = MD.decode_step(
            params, CFG, {"tokens": jnp.asarray([[1], [2]])}, pool)
        np.testing.assert_array_equal(np.asarray(pool["len"]), [5, 10])
        pos = np.asarray(pool["layers"]["s0"]["pos"])
        assert np.all(pos[:, 0, 4] == 4) and np.all(pos[:, 1, 9] == 9)
        assert np.all(pos[:, 0, 5:] == -1)       # nothing written beyond


def test_sample_token_greedy_and_top_k():
    logits = np.asarray([0.1, 3.0, 2.0, -1.0], np.float32)
    assert sample_token(logits, None) == 1
    assert sample_token(logits, SamplingParams()) == 1
    sp = SamplingParams(temperature=1.0, top_k=2, seed=0)
    picks = {sample_token(logits, sp, np.random.default_rng(s))
             for s in range(50)}
    assert picks <= {1, 2}                       # never outside top-2
