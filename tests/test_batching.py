"""Batching-library tests (paper §2.2.1): merging, buckets, timeout,
round-robin fairness, dynamic queues, load shedding, in-graph sections."""
import threading
import time

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # optional dep

from repro.batching import (BatchedSection, BatchingOptions,
                            BatchingQueue, BatchingSession,
                            QueueFullError, SharedBatchScheduler,
                            pow2_buckets)


class TestBuckets:
    def test_pow2_ladder(self):
        assert pow2_buckets(32) == [1, 2, 4, 8, 16, 32]
        assert pow2_buckets(48) == [1, 2, 4, 8, 16, 32, 48]

    @given(st.integers(1, 64), st.integers(1, 64))
    @settings(max_examples=200, deadline=None)
    def test_bucket_for_covers(self, maxb, n):
        opts = BatchingOptions(max_batch_size=maxb)
        if n <= maxb:
            b = opts.bucket_for(n)
            assert n <= b <= maxb
            assert b in opts.buckets()


class TestQueue:
    def test_closes_at_max_size(self):
        q = BatchingQueue("q", BatchingOptions(max_batch_size=4,
                                               batch_timeout_s=999))
        for _ in range(4):
            q.enqueue("x", size=1)
        batch = q.pop_ready_batch()
        assert batch is not None and batch.size == 4

    def test_timeout_closes_partial(self):
        q = BatchingQueue("q", BatchingOptions(max_batch_size=8,
                                               batch_timeout_s=0.01))
        q.enqueue("x", size=1)
        assert q.pop_ready_batch() is None      # not yet
        time.sleep(0.02)
        batch = q.pop_ready_batch()
        assert batch is not None and batch.size == 1

    def test_task_too_large_rejected(self):
        q = BatchingQueue("q", BatchingOptions(max_batch_size=4))
        with pytest.raises(ValueError):
            q.enqueue("x", size=5)

    def test_load_shedding(self):
        q = BatchingQueue("q", BatchingOptions(
            max_batch_size=1, max_enqueued_batches=2, batch_timeout_s=999))
        q.enqueue("a"), q.enqueue("b")
        with pytest.raises(QueueFullError):
            q.enqueue("c")
        assert q.stats["shed"] == 1

    @given(st.lists(st.integers(1, 8), min_size=1, max_size=40))
    @settings(max_examples=100, deadline=None)
    def test_no_task_lost_or_duplicated(self, sizes):
        """Property: every enqueued task appears in exactly one batch."""
        q = BatchingQueue("q", BatchingOptions(max_batch_size=8,
                                               batch_timeout_s=0))
        for i, s in enumerate(sizes):
            q.enqueue(i, size=s)
        seen = []
        while True:
            b = q.pop_ready_batch(force=True)
            if b is None:
                break
            assert b.size <= 8
            seen.extend(t.payload for t in b.tasks)
        assert sorted(seen) == list(range(len(sizes)))


class TestSessionAndScheduler:
    def setup_method(self):
        self.sched = SharedBatchScheduler()
        self.sched.start()

    def teardown_method(self):
        self.sched.stop()

    def test_merges_concurrent_requests(self):
        shapes = []

        def fn(x):
            shapes.append(x.shape)
            return x * 2
        sess = BatchingSession("m", fn, self.sched,
                               BatchingOptions(max_batch_size=16,
                                               batch_timeout_s=0.01))
        out = [None] * 10

        def worker(i):
            out[i] = sess.run(np.full((1, 3), float(i)))
        ts = [threading.Thread(target=worker, args=(i,))
              for i in range(10)]
        [t.start() for t in ts]
        [t.join() for t in ts]
        for i in range(10):
            assert np.allclose(out[i], 2.0 * i)
        assert len(shapes) < 10          # merging happened
        sess.close()

    def test_bucket_padding_shapes(self):
        shapes = []

        def fn(x):
            shapes.append(x.shape[0])
            return x
        sess = BatchingSession("m", fn, self.sched,
                               BatchingOptions(max_batch_size=8,
                                               batch_timeout_s=0.005))
        sess.run(np.ones((3, 2)))        # 3 -> bucket 4
        assert shapes[-1] == 4
        sess.run(np.ones((5, 2)))        # 5 -> bucket 8
        assert shapes[-1] == 8
        sess.close()

    def test_error_propagates_to_all_tasks(self):
        def fn(x):
            raise RuntimeError("boom")
        sess = BatchingSession("m", fn, self.sched,
                               BatchingOptions(batch_timeout_s=0.001))
        with pytest.raises(RuntimeError):
            sess.run(np.ones((1, 2)))
        sess.close()

    def test_round_robin_interleaves_two_models(self):
        """Paper: round-robin across queues onto one shared device — a
        hot model must not starve a cold one."""
        order = []

        def mk(name):
            def fn(x):
                order.append(name)
                time.sleep(0.001)
                return x
            return fn
        hot = BatchingSession("hot", mk("hot"), self.sched,
                              BatchingOptions(max_batch_size=1))
        cold = BatchingSession("cold", mk("cold"), self.sched,
                               BatchingOptions(max_batch_size=1))
        outs = []
        ts = [threading.Thread(
            target=lambda: outs.append(hot.run(np.ones((1, 1)))))
            for _ in range(20)]
        ts.append(threading.Thread(
            target=lambda: outs.append(cold.run(np.ones((1, 1))))))
        [t.start() for t in ts]
        [t.join() for t in ts]
        # the single cold request must have been served before the hot
        # stream fully drained (interleaving), not last
        idx = order.index("cold")
        assert idx < len(order) - 1
        hot.close(), cold.close()

    def test_dynamic_queue_removal_drains(self):
        done = []
        sess = BatchingSession("m", lambda x: done.append(1) or x,
                               self.sched,
                               BatchingOptions(max_batch_size=4,
                                               batch_timeout_s=999))
        t = sess.submit(np.ones((1, 1)))
        sess.close(drain=True)           # forces the partial batch out
        assert t.wait(1.0) is not None
        assert "m" not in self.sched.queue_names()

    def test_in_graph_sections_batch_independently(self):
        enc_shapes, dec_shapes = [], []

        def enc_fn(x):
            # slow processor: while the device chews on the first batch,
            # the remaining workers' tasks pile up and must merge (the
            # idle-device partial-pop path otherwise races to size-1
            # batches when workers trickle in)
            enc_shapes.append(x.shape[0])
            time.sleep(0.02)
            return x + 1
        enc = BatchedSection(
            enc_fn,
            self.sched, BatchingOptions(max_batch_size=4,
                                        batch_timeout_s=0.005),
            name="enc")
        dec = BatchedSection(
            lambda x: dec_shapes.append(x.shape[0]) or x * 3,
            self.sched, BatchingOptions(max_batch_size=4,
                                        batch_timeout_s=0.005),
            name="dec")
        results = [None] * 6

        def worker(i):
            h = enc(np.full((1, 2), float(i)))
            results[i] = dec(h)
        ts = [threading.Thread(target=worker, args=(i,))
              for i in range(6)]
        [t.start() for t in ts]
        [t.join() for t in ts]
        for i in range(6):
            assert np.allclose(results[i], (i + 1) * 3.0)
        assert len(enc_shapes) < 6 or len(dec_shapes) < 6
        enc.close(), dec.close()
